//! Durable session-store acceptance suite: crash-safe O(1) conversation
//! resume through the full serving stack, on host mocks (runs without
//! `make artifacts`).
//!
//! Pins the session contracts (rust/docs/robustness.md):
//!
//! - a resumed session's next turn is byte-identical to stateless
//!   full-history re-prefill, with ZERO prefill dispatches after turn 1 —
//!   pinned across a thousand-turn conversation
//! - a torn (truncated) record is quarantined by the recovery scan and
//!   the session degrades to re-prefill with identical bytes
//! - a bit-flipped record fails its checksum at load time, is quarantined
//!   to `*.corrupt`, and the turn re-prefills byte-identically
//! - an unwritable spill target loses evicted sessions on the persist
//!   side only — counted, degraded to re-prefill, never wrong bytes

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use ssm_peft::error::Result;
use ssm_peft::eval::{ChunkPrefill, DecodeState, StateDims, StepDecode};
use ssm_peft::serve::{
    LaneModel, Request, Response, Scheduler, ServeFactory, ServeModel, SessionStore,
};
use ssm_peft::tensor::{IntTensor, Tensor};

// ---------------------------------------------------------------- mocks
// Local rolling-hash decode mock (the crate's internal test mocks are not
// exported), chunk-capable so re-prefill cost is visible in the chunk
// counter. Every f32 op stays far below 2^24, so the recurrence is exact
// and byte-equivalence assertions are meaningful.

fn val(t: i32) -> f32 {
    if (0..256).contains(&t) {
        t as f32
    } else {
        1.0 // BOS / PAD
    }
}

fn advance(a: f32, prev: f32, t: i32) -> (f32, f32) {
    let v = val(t);
    ((a * 33.0 + v + prev + 2.0) % 251.0, v)
}

fn one_hot(b: usize, hashes: &[f32]) -> Tensor {
    let mut l = Tensor::zeros(&[b, 256]);
    for r in 0..b {
        l.data[r * 256 + (hashes[r] as usize) % 256] = 10.0;
    }
    l
}

fn mock_dims() -> StateDims {
    StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 }
}

/// Chunk-capable merged-lane mock with dispatch counters: `steps` counts
/// single-token dispatches, `chunks` counts prefill-chunk dispatches.
struct ChunkRoll {
    b: usize,
    widths: Vec<usize>,
    steps: AtomicU64,
    chunks: AtomicU64,
}

impl ChunkRoll {
    fn new(b: usize, widths: &[usize]) -> ChunkRoll {
        ChunkRoll {
            b,
            widths: widths.to_vec(),
            steps: AtomicU64::new(0),
            chunks: AtomicU64::new(0),
        }
    }
}

impl StepDecode for ChunkRoll {
    fn arch_b(&self) -> usize {
        self.b
    }
    fn dims(&self) -> StateDims {
        mock_dims()
    }
    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
        self.steps.fetch_add(1, Ordering::Relaxed);
        let (conv, ssm) = state.host_mut()?;
        let mut hashes = vec![0.0f32; self.b];
        for r in 0..self.b {
            let (a, v) = advance(ssm.data[r], conv.data[r], tokens.data[r]);
            ssm.data[r] = a;
            conv.data[r] = v;
            hashes[r] = a;
        }
        Ok(one_hot(self.b, &hashes))
    }
    fn chunk_prefill(&self) -> Option<&dyn ChunkPrefill> {
        if self.widths.is_empty() {
            None
        } else {
            Some(self)
        }
    }
}

impl ChunkPrefill for ChunkRoll {
    fn chunk_widths(&self) -> &[usize] {
        &self.widths
    }
    fn prefill_chunk(&self, tokens: &IntTensor, state: &mut DecodeState)
        -> Result<Tensor> {
        self.chunks.fetch_add(1, Ordering::Relaxed);
        let c = tokens.data.len() / self.b;
        let (conv, ssm) = state.host_mut()?;
        let mut hashes = vec![0.0f32; self.b];
        for r in 0..self.b {
            for j in 0..c {
                let (a, v) =
                    advance(ssm.data[r], conv.data[r], tokens.data[r * c + j]);
                ssm.data[r] = a;
                conv.data[r] = v;
                hashes[r] = a;
            }
        }
        Ok(one_hot(self.b, &hashes))
    }
}

fn factory(model: Arc<ChunkRoll>) -> ServeFactory<'static> {
    Box::new(move |_adapter: &str| {
        Ok(ServeModel::Merged(LaneModel { model: model.clone(), h0: None }))
    })
}

fn req(id: u64, session: Option<&str>, prompt: Vec<u8>, max_new: usize) -> Request {
    Request {
        id,
        adapter: "chat".into(),
        prompt,
        max_new,
        // hashes land in [0, 250], so generation always runs to max_new
        stop_byte: 255,
        beam: 1,
        deadline: 0,
        session: session.map(str::to_string),
    }
}

fn first_prompt() -> Vec<u8> {
    (0..16).map(|i| ((i * 11 + 5) % 199 + 1) as u8).collect()
}

/// Turn t's follow-up: previous prompt ++ previous output ++ a fresh byte.
fn next_turn(prev: &[u8], out: &[u8], t: u64) -> Vec<u8> {
    let mut p = prev.to_vec();
    p.extend_from_slice(out);
    p.push((t % 191 + 1) as u8);
    p
}

/// Ground truth: the same prompt as a fresh stateless request.
fn stateless_reference(prompt: Vec<u8>, max_new: usize) -> Response {
    let model = Arc::new(ChunkRoll::new(1, &[8, 32]));
    let mut sched = Scheduler::new(factory(model), 2);
    sched.submit(req(900, None, prompt, max_new));
    let r = sched.run_to_completion().pop().expect("reference retires");
    assert!(r.error.is_none(), "reference failed: {:?}", r.error);
    r
}

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("ssm-peft-session-durability-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// The single spilled `.session` record under `dir`.
fn session_record(dir: &Path) -> PathBuf {
    let mut found: Vec<PathBuf> = std::fs::read_dir(dir)
        .expect("spill dir exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "session"))
        .collect();
    assert_eq!(found.len(), 1, "exactly one spilled record: {found:?}");
    found.pop().expect("one record")
}

fn corrupt_count(dir: &Path) -> usize {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.path().extension().is_some_and(|x| x == "corrupt"))
                .count()
        })
        .unwrap_or(0)
}

/// Run turn 1 of a session against a spill dir and drain, leaving exactly
/// one durable record behind; returns turn 2's prompt.
fn drained_turn_one(dir: &Path, sid: &str, max_new: usize) -> Vec<u8> {
    let model = Arc::new(ChunkRoll::new(1, &[8, 32]));
    let mut sched = Scheduler::new(factory(model), 2);
    sched.set_session_store(Arc::new(SessionStore::new(4).with_dir(dir)));
    sched.submit(req(1, Some(sid), first_prompt(), max_new));
    let (mut resps, flushed, failed) = sched.drain();
    assert_eq!((flushed, failed), (1, 0), "drain flushes the one session");
    let r = resps.pop().expect("turn 1 retires");
    assert!(r.error.is_none(), "turn 1 failed: {:?}", r.error);
    next_turn(&first_prompt(), &r.output, 1)
}

// ---------------------------------------------------------------- tests

#[test]
fn thousand_turn_conversation_prefills_exactly_once() {
    let model = Arc::new(ChunkRoll::new(1, &[8, 32]));
    let mut sched = Scheduler::new(factory(model.clone()), 2);
    sched.set_session_store(Arc::new(SessionStore::new(4)));
    let mut prompt = first_prompt();
    let mut chunks_after_turn_one = 0;
    for t in 0..1000u64 {
        sched.submit(req(t, Some("marathon"), prompt.clone(), 2));
        let r = sched.run_to_completion().pop().expect("turn retires");
        assert!(r.error.is_none(), "turn {t} failed: {:?}", r.error);
        assert_eq!(r.output.len(), 2, "turn {t} ran to max_new");
        prompt = next_turn(&prompt, &r.output, t);
        if t == 0 {
            chunks_after_turn_one = model.chunks.load(Ordering::Relaxed);
            assert!(chunks_after_turn_one > 0, "turn 1 prefills in chunks");
        }
    }
    assert_eq!(
        model.chunks.load(Ordering::Relaxed),
        chunks_after_turn_one,
        "zero prefill dispatches after turn 1, across 999 resumed turns"
    );
    assert_eq!(sched.session_resurrections, 999);
    assert_eq!(sched.session_fallbacks, 0);
    assert_eq!(sched.session_persists, 1000);
    // and the resumed tail is byte-identical to a stateless replay: the
    // final turn's prompt encodes every previous output, so one reference
    // decode of it checks the whole chain
    let model2 = Arc::new(ChunkRoll::new(1, &[8, 32]));
    let mut s2 = Scheduler::new(factory(model2), 2);
    s2.set_session_store(Arc::new(SessionStore::new(4)));
    s2.submit(req(2000, Some("marathon-check"), prompt.clone(), 2));
    let got = s2.run_to_completion().pop().expect("check turn retires");
    let want = stateless_reference(prompt, 2);
    assert_eq!(got.output, want.output);
}

#[test]
fn truncated_record_is_quarantined_then_reprefilled_byte_identically() {
    let dir = tdir("truncate");
    let prompt2 = drained_turn_one(&dir, "torn", 3);
    // a torn write: the record loses its tail (checksum and part of the
    // payload) as if the machine died mid-flush
    let path = session_record(&dir);
    let bytes = std::fs::read(&path).expect("record readable");
    std::fs::write(&path, &bytes[..bytes.len() / 2]).expect("truncate");
    // restart: the recovery scan quarantines the torn record up front
    let store = Arc::new(SessionStore::new(4).with_dir(&dir));
    let rec = store.recover();
    assert_eq!((rec.valid, rec.quarantined), (0, 1), "{rec:?}");
    assert_eq!(corrupt_count(&dir), 1, "quarantined to *.corrupt, not deleted");
    assert!(!path.exists(), "the torn record itself is gone");
    let model = Arc::new(ChunkRoll::new(1, &[8, 32]));
    let mut sched = Scheduler::new(factory(model.clone()), 2);
    sched.set_session_store(store);
    sched.submit(req(2, Some("torn"), prompt2.clone(), 3));
    let r2 = sched.run_to_completion().pop().expect("turn 2 retires");
    assert!(r2.error.is_none(), "degradation must not surface: {:?}", r2.error);
    let want = stateless_reference(prompt2, 3);
    assert_eq!(r2.output, want.output, "re-prefilled turn is byte-identical");
    assert_eq!(sched.session_resurrections, 0);
    assert_eq!(
        sched.session_fallbacks, 0,
        "post-recovery the miss is clean, not an error"
    );
    assert!(model.chunks.load(Ordering::Relaxed) > 0, "full prefill re-ran");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bit_flipped_record_fails_checksum_at_load_and_reprefills() {
    let dir = tdir("bitflip");
    let prompt2 = drained_turn_one(&dir, "flipped", 3);
    // one flipped bit in the middle of the payload
    let path = session_record(&dir);
    let mut bytes = std::fs::read(&path).expect("record readable");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).expect("flip");
    // no recovery scan this time: the load path itself must catch it
    let store = Arc::new(SessionStore::new(4).with_dir(&dir));
    let model = Arc::new(ChunkRoll::new(1, &[8, 32]));
    let mut sched = Scheduler::new(factory(model), 2);
    sched.set_session_store(store.clone());
    sched.submit(req(2, Some("flipped"), prompt2.clone(), 3));
    let r2 = sched.run_to_completion().pop().expect("turn 2 retires");
    assert!(r2.error.is_none(), "degradation must not surface: {:?}", r2.error);
    let want = stateless_reference(prompt2, 3);
    assert_eq!(r2.output, want.output, "re-prefilled turn is byte-identical");
    assert_eq!(sched.session_resurrections, 0);
    assert_eq!(sched.session_fallbacks, 1, "typed degradation, counted");
    assert_eq!(store.stats().quarantined, 1);
    assert_eq!(corrupt_count(&dir), 1);
    assert!(!path.exists(), "the corrupt record is never trusted again");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unwritable_spill_target_loses_evictions_but_never_bytes() {
    // the "spill dir" is a FILE, so every eviction spill fails — the
    // moral equivalent of a full disk, deterministic and portable
    let parent = tdir("blocked");
    std::fs::create_dir_all(&parent).expect("parent dir");
    let blocked = parent.join("spill");
    std::fs::write(&blocked, b"not a directory").expect("blocker file");
    let model = Arc::new(ChunkRoll::new(1, &[8, 32]));
    let mut sched = Scheduler::new(factory(model), 2);
    let store = Arc::new(SessionStore::new(1).with_dir(&blocked));
    sched.set_session_store(store.clone());
    // turn 1 of session A persists into the memory tier (cap 1)
    sched.submit(req(1, Some("session-a"), first_prompt(), 3));
    let ra = sched.run_to_completion().pop().expect("A turn 1 retires");
    assert!(ra.error.is_none(), "{:?}", ra.error);
    // session B's snapshot evicts A; A's spill hits the blocked target
    // and is lost — counted, not an error
    let other: Vec<u8> = (0..20).map(|i| ((i * 13 + 7) % 199 + 1) as u8).collect();
    sched.submit(req(2, Some("session-b"), other, 3));
    let rb = sched.run_to_completion().pop().expect("B turn 1 retires");
    assert!(rb.error.is_none(), "{:?}", rb.error);
    assert!(store.stats().persist_failures >= 1, "lost spill is counted");
    assert_eq!(store.stats().spills, 0, "nothing durably spilled");
    // A's next turn re-prefills from scratch, byte-identical to stateless
    let prompt2 = next_turn(&first_prompt(), &ra.output, 1);
    sched.submit(req(3, Some("session-a"), prompt2.clone(), 3));
    let r2 = sched.run_to_completion().pop().expect("A turn 2 retires");
    assert!(r2.error.is_none(), "degradation must not surface: {:?}", r2.error);
    let want = stateless_reference(prompt2, 3);
    assert_eq!(r2.output, want.output, "re-prefilled turn is byte-identical");
    assert_eq!(sched.session_resurrections, 0, "A was never resurrected");
    let _ = std::fs::remove_dir_all(&parent);
}
