//! Robustness acceptance suite: deterministic seeded fault injection
//! through the full serving stack, on host mocks (runs without `make
//! artifacts`; CI's `fault-smoke` step executes exactly this file).
//!
//! Pins the PR-8 contracts (rust/docs/robustness.md):
//!
//! - faults disabled => bytes and step counts identical to a fault-free run
//! - transient exec faults are retried in place after rollback, and the
//!   retried request's bytes match a fault-free reference
//! - terminal faults produce typed `failed:*` finishes, never hangs
//! - a poisoned adapter in the shared batch demotes the batch to merged
//!   lanes, gets quarantined by the circuit breaker, and leaves innocent
//!   rows byte-identical to solo runs
//! - registry pins balance to zero after churn with injected errors
//! - deadlines and the tick budget bound every request's lifetime

use std::collections::BTreeMap;
use std::sync::Arc;

use ssm_peft::error::{Error, ErrorKind, Result};
use ssm_peft::eval::{
    AdapterDelta, AdapterRow, AdapterStepDecode, DecodeState, SparseOffset, StateDims,
    StepDecode,
};
use ssm_peft::fault::{FaultInject, FaultPlan, FaultSite};
use ssm_peft::manifest::PeftMeta;
use ssm_peft::serve::{
    Adapter, AdapterRegistry, LaneModel, Request, Response, Scheduler, ServeFactory,
    ServeModel,
};
use ssm_peft::suite::PeftMethod;
use ssm_peft::tensor::{IntTensor, Rng, Tensor};

// ---------------------------------------------------------------- mocks
// Local rolling-hash decode mocks (the crate's internal test mocks are
// not exported): every f32 op stays far below 2^24, so the recurrence is
// exact and byte-equivalence assertions are meaningful.

fn val(t: i32) -> f32 {
    if (0..256).contains(&t) {
        t as f32
    } else {
        1.0 // BOS / PAD
    }
}

fn advance(a: f32, prev: f32, t: i32, off: f32) -> (f32, f32) {
    let v = val(t);
    ((a * 33.0 + v + prev + off) % 251.0, v)
}

fn one_hot(b: usize, hashes: &[f32]) -> Tensor {
    let mut l = Tensor::zeros(&[b, 256]);
    for r in 0..b {
        l.data[r * 256 + (hashes[r] as usize) % 256] = 10.0;
    }
    l
}

fn mock_dims() -> StateDims {
    StateDims { n_layer: 1, d_conv: 2, d_inner: 1, d_state: 1 }
}

/// Merged-lane mock: one model-wide hash offset stands in for "merged
/// adapter weights".
struct Roll {
    b: usize,
    off: f32,
}

impl StepDecode for Roll {
    fn arch_b(&self) -> usize {
        self.b
    }
    fn dims(&self) -> StateDims {
        mock_dims()
    }
    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
        let (conv, ssm) = state.host_mut()?;
        let mut hashes = vec![0.0f32; self.b];
        for r in 0..self.b {
            let (a, v) = advance(ssm.data[r], conv.data[r], tokens.data[r], self.off);
            ssm.data[r] = a;
            conv.data[r] = v;
            hashes[r] = a;
        }
        Ok(one_hot(self.b, &hashes))
    }
}

/// [`Roll`] whose exec site consults a fault plan BEFORE touching state
/// (the real `DecodeCore::run_exec` ordering), so a faulted step leaves
/// the state untouched and a post-rollback retry is byte-identical.
struct FaultyRoll {
    inner: Roll,
    plan: Arc<FaultPlan>,
}

impl StepDecode for FaultyRoll {
    fn arch_b(&self) -> usize {
        self.inner.arch_b()
    }
    fn dims(&self) -> StateDims {
        self.inner.dims()
    }
    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
        self.plan.check(FaultSite::ExecRun)?;
        self.inner.step(tokens, state)
    }
}

/// Shared-lane mock: each row's offset comes from that row's delta (first
/// sparse value). `poison` marks one offset as a corrupt adapter whose
/// presence fails the whole batched dispatch — the scenario the
/// degradation cascade exists for.
struct RollShared {
    b: usize,
    plan: Option<Arc<FaultPlan>>,
    poison: Option<f32>,
}

fn row_off(row: &AdapterRow) -> f32 {
    row.as_ref()
        .and_then(|d| d.sparse.first())
        .and_then(|s| s.val.first())
        .copied()
        .unwrap_or(0.0)
}

impl StepDecode for RollShared {
    fn arch_b(&self) -> usize {
        self.b
    }
    fn dims(&self) -> StateDims {
        mock_dims()
    }
    fn step(&self, tokens: &IntTensor, state: &mut DecodeState) -> Result<Tensor> {
        let rows: Vec<AdapterRow> = vec![None; self.b];
        self.step_rows(tokens, state, &rows)
    }
}

impl AdapterStepDecode for RollShared {
    fn step_rows(&self, tokens: &IntTensor, state: &mut DecodeState,
                 rows: &[AdapterRow]) -> Result<Tensor> {
        assert_eq!(rows.len(), self.b);
        if let Some(p) = &self.plan {
            p.check(FaultSite::ExecRun)?;
        }
        if let Some(bad) = self.poison {
            if rows.iter().any(|r| row_off(r) == bad) {
                return Err(Error::new(
                    ErrorKind::Invariant,
                    "poisoned adapter delta in batch",
                ));
            }
        }
        let (conv, ssm) = state.host_mut()?;
        let mut hashes = vec![0.0f32; self.b];
        for r in 0..self.b {
            let (a, v) =
                advance(ssm.data[r], conv.data[r], tokens.data[r], row_off(&rows[r]));
            ssm.data[r] = a;
            conv.data[r] = v;
            hashes[r] = a;
        }
        Ok(one_hot(self.b, &hashes))
    }
}

/// Merged lane standing in for unusably corrupt adapter parameters.
struct FailingStep;

impl StepDecode for FailingStep {
    fn arch_b(&self) -> usize {
        1
    }
    fn dims(&self) -> StateDims {
        mock_dims()
    }
    fn step(&self, _tokens: &IntTensor, _state: &mut DecodeState) -> Result<Tensor> {
        Err(Error::new(ErrorKind::Invariant, "poisoned adapter parameters"))
    }
}

fn delta(off: f32) -> Arc<AdapterDelta> {
    Arc::new(AdapterDelta {
        meta: PeftMeta {
            method: PeftMethod::Sdt,
            rank: 0,
            alpha: 0,
            targets: Vec::new(),
            n_tokens: 0,
        },
        lora: Vec::new(),
        sparse: vec![SparseOffset { param: "off".into(), idx: vec![0], val: vec![off] }],
        h0: BTreeMap::new(),
    })
}

fn req(id: u64, adapter: &str, max_new: usize) -> Request {
    Request {
        id,
        adapter: adapter.into(),
        prompt: vec![(id * 7 % 200) as u8 + 1, 42],
        max_new,
        // hashes land in [0, 250], so generation always runs to max_new
        stop_byte: 255,
        beam: 1,
        deadline: 0,
        session: None,
    }
}

/// Run `reqs` through a fresh scheduler to completion, sorted by id.
fn drive(factory: ServeFactory, reqs: Vec<Request>) -> Vec<Response> {
    let mut sched = Scheduler::new(factory, 4);
    for r in reqs {
        sched.submit(r);
    }
    let mut out = sched.run_to_completion();
    out.sort_by_key(|r| r.id);
    out
}

/// Fault-free reference: the same request on a dedicated merged lane.
fn solo(off: f32, r: Request) -> Response {
    let factory: ServeFactory = Box::new(move |_: &str| {
        Ok(ServeModel::Merged(LaneModel { model: Arc::new(Roll { b: 1, off }), h0: None }))
    });
    drive(factory, vec![r]).pop().unwrap()
}

// ---------------------------------------------------------------- tests

#[test]
fn disabled_faults_leave_bytes_and_steps_identical() {
    // installing the fault layer with an empty plan (no rates, no
    // schedule) must not change a single byte or step count
    let mk_factory = || -> ServeFactory {
        Box::new(|a: &str| {
            let off = if a == "a" { 3.0 } else { 5.0 };
            Ok(ServeModel::Merged(LaneModel {
                model: Arc::new(Roll { b: 1, off }),
                h0: None,
            }))
        })
    };
    let reqs = vec![req(1, "a", 8), req(2, "b", 6)];
    let want = drive(mk_factory(), reqs.clone());

    let mut sched = Scheduler::new(mk_factory(), 4);
    let plan = Arc::new(FaultPlan::seeded(7)); // empty: never injects
    sched.set_fault_inject(plan.clone());
    for r in reqs {
        sched.submit(r);
    }
    let mut got = sched.run_to_completion();
    got.sort_by_key(|r| r.id);

    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert!(g.error.is_none(), "request {} failed: {:?}", g.id, g.error);
        assert_eq!(g.output, w.output, "fault layer perturbed request {}", g.id);
        assert_eq!(g.steps, w.steps, "fault layer changed step count for {}", g.id);
    }
    assert_eq!(plan.injected(FaultSite::ExecRun), 0);
}

#[test]
fn transient_exec_fault_retries_to_identical_bytes() {
    // a single transient exec fault rolls back, retries in place, and the
    // finished request is byte-identical to a fault-free reference
    let plan = Arc::new(FaultPlan::seeded(5).with_fault_at(FaultSite::ExecRun, 2));
    let p = plan.clone();
    let factory: ServeFactory = Box::new(move |_: &str| {
        Ok(ServeModel::Merged(LaneModel {
            model: Arc::new(FaultyRoll { inner: Roll { b: 1, off: 4.0 }, plan: p.clone() }),
            h0: None,
        }))
    });
    let mut sched = Scheduler::new(factory, 4);
    sched.set_fault_inject(plan.clone());
    sched.submit(req(1, "a", 8));
    let out = sched.run_to_completion();

    assert_eq!(out.len(), 1);
    assert!(out[0].error.is_none(), "retry did not recover: {:?}", out[0].error);
    assert_eq!(out[0].output, solo(4.0, req(1, "a", 8)).output);
    assert_eq!(plan.injected(FaultSite::ExecRun), 1);
    assert_eq!(sched.step_faults, 1);
    assert_eq!(sched.step_retries, 1);
}

#[test]
fn terminal_exec_fault_types_the_failure() {
    // a non-transient fault is not retried: the request retires with a
    // typed `failed:*` finish carrying the injected error
    let plan = Arc::new(
        FaultPlan::seeded(6)
            .with_fault_at(FaultSite::ExecRun, 1)
            .with_kind(ErrorKind::Invariant),
    );
    let p = plan.clone();
    let factory: ServeFactory = Box::new(move |_: &str| {
        Ok(ServeModel::Merged(LaneModel {
            model: Arc::new(FaultyRoll { inner: Roll { b: 1, off: 2.0 }, plan: p.clone() }),
            h0: None,
        }))
    });
    let mut sched = Scheduler::new(factory, 4);
    sched.set_fault_inject(plan);
    sched.submit(req(1, "a", 8));
    let out = sched.run_to_completion();

    assert_eq!(out.len(), 1);
    assert_eq!(out[0].finish.label(), "failed:invariant");
    let msg = out[0].error.as_deref().unwrap_or("");
    assert!(msg.contains("injected fault"), "error lost its cause: {msg}");
    assert_eq!(sched.step_retries, 0);
}

#[test]
fn readback_fault_disables_retry_for_transient_step() {
    // when the pre-step checkpoint itself cannot be taken (state readback
    // faults), a transient step error has nothing to roll back to and
    // must fail terminally instead of retrying on corrupt state
    let plan = Arc::new(
        FaultPlan::seeded(8)
            .with_fault_at(FaultSite::ExecRun, 2)
            .with_rate(FaultSite::StateReadback, 1.0),
    );
    let p = plan.clone();
    let factory: ServeFactory = Box::new(move |_: &str| {
        Ok(ServeModel::Merged(LaneModel {
            model: Arc::new(FaultyRoll { inner: Roll { b: 1, off: 2.0 }, plan: p.clone() }),
            h0: None,
        }))
    });
    let mut sched = Scheduler::new(factory, 4);
    sched.set_fault_inject(plan);
    sched.submit(req(1, "a", 8));
    let out = sched.run_to_completion();

    assert_eq!(out.len(), 1);
    assert_eq!(out[0].finish.label(), "failed:runtime");
    assert_eq!(sched.step_retries, 0, "retried without a rollback point");
}

#[test]
fn poisoned_adapter_demotes_batch_quarantines_and_spares_innocents() {
    // one corrupt adapter joins a healthy shared batch: the batch demotes
    // to merged lanes, innocents finish byte-identical to solo runs, the
    // bad adapter fails typed and trips the circuit breaker, and later
    // requests for it are rejected as quarantined
    let off_of = |name: &str| match name {
        "a" => 3.0,
        "b" => 5.0,
        _ => 13.0,
    };
    let source = move |name: &str| -> Result<Adapter> {
        Ok(Adapter {
            name: name.to_string(),
            decode_variant: "mock_full".to_string(),
            delta: Some(delta(off_of(name))),
            h0: None,
            budget_pct: 0.0,
        })
    };
    let mut registry = AdapterRegistry::new(source, 8);
    registry.set_quarantine_threshold(1);
    let registry = registry;

    let shared: Arc<RollShared> =
        Arc::new(RollShared { b: 4, plan: None, poison: Some(13.0) });

    let factory: ServeFactory = Box::new(|name: &str| {
        let a = registry.get(name)?;
        registry.pin(name);
        let model: Arc<dyn AdapterStepDecode> = shared.clone();
        Ok(ServeModel::Shared { model, delta: a.delta.clone(), h0: None })
    });
    let mut sched = Scheduler::new(factory, 4);
    sched.on_release(Box::new(|name: &str| registry.unpin(name)));
    sched.on_adapter_failure(Box::new(|name: &str, _kind| {
        registry.record_failure(name);
    }));
    sched.set_merged_fallback(Box::new(|name: &str| {
        let a = registry.get(name)?;
        let model: Arc<dyn StepDecode> = if name == "bad" {
            Arc::new(FailingStep)
        } else {
            Arc::new(Roll { b: 1, off: row_off(&a.delta) })
        };
        Ok(LaneModel { model, h0: None })
    }));

    sched.submit(req(1, "a", 8));
    sched.submit(req(2, "b", 6));
    sched.submit(req(3, "bad", 8));
    let mut out = sched.run_to_completion();
    out.sort_by_key(|r| r.id);

    assert_eq!(out.len(), 3);
    // innocents: demoted exactly once, bytes identical to solo merged runs
    for (resp, name, max_new) in [(&out[0], "a", 8), (&out[1], "b", 6)] {
        assert!(resp.error.is_none(), "innocent {name} failed: {:?}", resp.error);
        assert_eq!(resp.retries, 1, "innocent {name} not demoted exactly once");
        let reference = solo(off_of(name), req(resp.id, name, max_new));
        assert_eq!(resp.output, reference.output, "innocent {name} bytes drifted");
    }
    // the bad adapter: typed terminal failure, quarantined, pins balanced
    assert_eq!(out[2].finish.label(), "failed:invariant");
    assert!(registry.is_quarantined("bad"));
    assert!(!registry.is_quarantined("a"));
    assert_eq!(sched.demotions, 3);
    assert_eq!(registry.stats().pins, 0, "leaked adapter pins");

    // a follow-up request for the quarantined adapter is rejected typed
    sched.submit(req(4, "bad", 4));
    let rejected = sched.run_to_completion();
    assert_eq!(rejected.len(), 1);
    assert_eq!(rejected[0].finish.label(), "failed:request");
    let msg = rejected[0].error.as_deref().unwrap_or("");
    assert!(msg.contains("quarantined"), "rejection lost its cause: {msg}");

    // ...until an operator reinstates it
    registry.reinstate("bad");
    assert!(!registry.is_quarantined("bad"));
    assert!(registry.get("bad").is_ok());
}

/// [`AdapterSource`] for the churn matrix: every name materializes, the
/// poisoned one included (its *decode* is what fails, not its load), and
/// merged materialization succeeds so the fallback path is reachable.
struct MockSource;

impl ssm_peft::serve::AdapterSource for MockSource {
    fn load(&self, name: &str) -> Result<Adapter> {
        let off = if name == "bad" { 13.0 } else { name.len() as f32 + 2.0 };
        Ok(Adapter {
            name: name.to_string(),
            decode_variant: "mock_full".to_string(),
            delta: Some(delta(off)),
            h0: None,
            budget_pct: 0.0,
        })
    }
    fn load_merged(&self, _name: &str) -> Result<BTreeMap<String, Tensor>> {
        Ok(BTreeMap::new()) // mock lanes carry their params in `off`
    }
}

#[test]
fn fault_matrix_churn_terminates_typed_with_balanced_pins() {
    // the fault matrix: seeded churn with faults injected at EVERY site
    // (exec, adapter load, artifact read, state readback) plus one
    // poisoned adapter. Properties: no panic, every request terminates
    // with a typed finish, the poisoned adapter trips the breaker, and no
    // registry pin leaks.
    let mut registry = AdapterRegistry::new(MockSource, 4);
    registry.set_quarantine_threshold(2);
    let plan = Arc::new(
        FaultPlan::seeded(42)
            .with_rate(FaultSite::ExecRun, 0.15)
            .with_rate(FaultSite::AdapterLoad, 0.05)
            .with_rate(FaultSite::ArtifactRead, 0.05)
            .with_rate(FaultSite::StateReadback, 0.02),
    );
    registry.set_fault_inject(plan.clone());
    let registry = registry;
    let shared: Arc<RollShared> =
        Arc::new(RollShared { b: 4, plan: Some(plan.clone()), poison: Some(13.0) });

    let factory: ServeFactory = Box::new(|name: &str| {
        let a = registry.get(name)?;
        registry.pin(name);
        let model: Arc<dyn AdapterStepDecode> = shared.clone();
        Ok(ServeModel::Shared { model, delta: a.delta.clone(), h0: None })
    });
    let mut sched = Scheduler::new(factory, 4);
    sched.set_fault_inject(plan.clone());
    sched.on_release(Box::new(|name: &str| registry.unpin(name)));
    sched.on_adapter_failure(Box::new(|name: &str, _kind| {
        registry.record_failure(name);
    }));
    sched.set_merged_fallback(Box::new(|name: &str| {
        let a = registry.get(name)?;
        let _params = registry.load_merged(name)?; // exercises artifact_read
        let model: Arc<dyn StepDecode> = if name == "bad" {
            Arc::new(FailingStep)
        } else {
            Arc::new(Roll { b: 1, off: row_off(&a.delta) })
        };
        Ok(LaneModel { model, h0: None })
    }));

    let names = ["alpha", "beta", "gamma", "delta", "eps"];
    let mut rng = Rng::new(99);
    let total = 33u64;
    for id in 0..total {
        let name = if id % 11 == 10 {
            "bad" // 3 poisoned requests interleaved with the healthy churn
        } else {
            names[(rng.uniform() * names.len() as f32) as usize % names.len()]
        };
        sched.submit(req(id, name, 4 + (id % 5) as usize));
    }
    let out = sched.run_to_completion();

    assert_eq!(out.len() as u64, total, "requests lost under injected faults");
    assert!(sched.is_idle());
    for r in &out {
        let label = r.finish.label();
        assert!(
            label == "stop" || label == "length" || label.starts_with("failed:"),
            "request {} finished untyped: {label}",
            r.id
        );
        if r.adapter == "bad" {
            assert!(label.starts_with("failed:"), "poisoned request {} passed", r.id);
        }
    }
    // every fault site was actually exercised by the churn
    for site in [
        FaultSite::ExecRun,
        FaultSite::AdapterLoad,
        FaultSite::ArtifactRead,
        FaultSite::StateReadback,
    ] {
        assert!(plan.checks(site) > 0, "site {} never checked", site.label());
    }
    assert!(plan.injected(FaultSite::ExecRun) > 0, "exec fault rate never fired");
    assert!(sched.step_retries > 0, "no transient fault was retried");
    assert!(registry.is_quarantined("bad"), "poisoned adapter not quarantined");
    assert_eq!(registry.stats().pins, 0, "leaked adapter pins after churn");
}

#[test]
fn deadline_expires_queued_request_under_load() {
    // a queued request whose deadline lapses while a long request hogs the
    // only lane retires typed, with zero decode steps burned
    let factory: ServeFactory = Box::new(|a: &str| {
        let off = if a == "a" { 3.0 } else { 5.0 };
        Ok(ServeModel::Merged(LaneModel {
            model: Arc::new(Roll { b: 1, off }),
            h0: None,
        }))
    });
    let mut sched = Scheduler::new(factory, 1);
    sched.submit(req(1, "a", 20));
    let mut starved = req(2, "b", 4);
    starved.deadline = 3;
    sched.submit(starved);
    let mut out = sched.run_to_completion();
    out.sort_by_key(|r| r.id);

    assert_eq!(out.len(), 2);
    assert!(out[0].error.is_none());
    assert_eq!(out[1].finish.label(), "failed:exhausted");
    assert_eq!(out[1].steps, 0, "expired request burned decode steps");
    let msg = out[1].error.as_deref().unwrap_or("");
    assert!(msg.contains("deadline"), "error lost its cause: {msg}");
    assert_eq!(sched.deadline_failures, 1);
}

#[test]
fn tick_budget_drains_everything_typed() {
    // the max-tick budget is a global liveness backstop: when it expires,
    // every resident and queued request drains as `failed:exhausted`
    // instead of hanging the caller
    let factory: ServeFactory = Box::new(|_: &str| {
        Ok(ServeModel::Merged(LaneModel {
            model: Arc::new(Roll { b: 1, off: 2.0 }),
            h0: None,
        }))
    });
    let mut sched = Scheduler::new(factory, 2);
    sched.set_max_run_ticks(5);
    sched.submit(req(1, "a", 1000));
    sched.submit(req(2, "b", 1000));
    let out = sched.run_to_completion();

    assert_eq!(out.len(), 2);
    for r in &out {
        assert_eq!(r.finish.label(), "failed:exhausted", "request {}", r.id);
        let msg = r.error.as_deref().unwrap_or("");
        assert!(msg.contains("tick budget"), "error lost its cause: {msg}");
    }
    assert!(sched.is_idle());
}
