//! repolint self-check: the tree must lint clean, the allowlist ledger must
//! not grow, and the rules must behave as specified on the fixture corpus
//! under `tests/lint_fixtures/`.
//!
//! Fixtures are data, not compiled code: they live in a subdirectory of
//! `tests/` (cargo only builds top-level files) and are excluded from the
//! lint walk itself, so they may violate rules on purpose.

use std::path::Path;

use ssm_peft::lint::allowlist::{ALLOWLIST, MAX_ENTRIES};
use ssm_peft::lint::rules::{check_file, Rule, Violation};
use ssm_peft::lint::{lexer, run, workspace_root};

/// Lex + rule-check one fixture file, presenting it under `rel` so the
/// right scopes apply.
fn check_fixture(name: &str, rel: &str) -> Vec<Violation> {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", path.display()));
    check_file(rel, &lexer::scan(&src)).0
}

fn lines_of(v: &[Violation], rule: Rule) -> Vec<usize> {
    let mut out: Vec<usize> =
        v.iter().filter(|x| x.rule == rule).map(|x| x.line).collect();
    out.sort_unstable();
    out.dedup();
    out
}

#[test]
fn workspace_lints_clean() {
    let report = run(&workspace_root()).expect("lint pass must complete");
    assert!(
        report.ok(),
        "repolint found problems:\n{}",
        report.render()
    );
    // zero-growth pins: the two ledgered panic sites, and nothing more.
    assert_eq!(
        report.allowlisted, 2,
        "allowlisted hit count drifted — update the ledger AND \
         rust/docs/linting.md together"
    );
    assert!(
        report.files_scanned >= 20,
        "suspiciously few files scanned ({}) — did the walk break?",
        report.files_scanned
    );
}

#[test]
fn ledger_is_bounded() {
    assert!(
        ALLOWLIST.len() <= MAX_ENTRIES,
        "allowlist ledger has {} entries, ceiling is {MAX_ENTRIES}",
        ALLOWLIST.len()
    );
}

#[test]
fn unsafe_inventory_fully_justified() {
    let report = run(&workspace_root()).expect("lint pass must complete");
    for site in &report.unsafe_sites {
        assert!(
            !site.justification.is_empty(),
            "{}:{} has an unsafe site without a SAFETY: comment: {}",
            site.file,
            site.line,
            site.excerpt
        );
    }
    // the runtime byte-view transmutes must be in the inventory
    assert!(
        report
            .unsafe_sites
            .iter()
            .any(|s| s.file == "rust/src/runtime/mod.rs"),
        "runtime transmute sites missing from the unsafe inventory"
    );
}

#[test]
fn fixture_no_panic() {
    let v = check_fixture("fail_no_panic.rs", "rust/src/fixture.rs");
    assert_eq!(lines_of(&v, Rule::NoPanic), vec![5, 6, 8, 11, 14], "{v:?}");

    let v = check_fixture("pass_no_panic.rs", "rust/src/fixture.rs");
    assert!(v.is_empty(), "{v:?}");

    // same file outside rust/src/ is out of scope entirely
    let v = check_fixture("fail_no_panic.rs", "rust/benches/fixture.rs");
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn fixture_unsafe_safety() {
    let v = check_fixture("fail_unsafe.rs", "rust/src/fixture.rs");
    assert_eq!(lines_of(&v, Rule::UnsafeSafety), vec![4, 8], "{v:?}");

    let v = check_fixture("pass_unsafe.rs", "rust/src/fixture.rs");
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn fixture_determinism() {
    // scoped: presented as the fused-optimizer file
    let v = check_fixture("fail_determinism.rs", "rust/src/optim.rs");
    assert_eq!(lines_of(&v, Rule::Determinism), vec![4, 5, 8, 9, 10], "{v:?}");

    let v = check_fixture("pass_determinism.rs", "rust/src/optim.rs");
    assert!(v.is_empty(), "{v:?}");

    // the same nondeterminism outside the scope list is not the lint's business
    let v = check_fixture("fail_determinism.rs", "rust/src/fixture.rs");
    assert!(lines_of(&v, Rule::Determinism).is_empty(), "{v:?}");
}

#[test]
fn fixture_knob_registry() {
    let v = check_fixture("fail_knob.rs", "rust/src/fixture.rs");
    assert_eq!(lines_of(&v, Rule::KnobRegistry), vec![5], "{v:?}");

    let v = check_fixture("pass_knob.rs", "rust/src/fixture.rs");
    assert!(v.is_empty(), "{v:?}");

    // the registry itself is exempt — it is where raw reads belong
    let v = check_fixture("fail_knob.rs", "rust/src/knobs.rs");
    assert!(lines_of(&v, Rule::KnobRegistry).is_empty(), "{v:?}");
}
