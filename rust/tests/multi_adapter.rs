//! Mixed-adapter byte-equivalence harness over the real AOT artifacts:
//! the acceptance gate for unmerged batched multi-adapter decode. One
//! shared batch carrying different per-row [`AdapterDelta`]s must produce
//! the SAME BYTES, row for row, as dedicated whole-model merged lanes —
//! including under mid-stream admission and through beam search. These
//! tests skip (with a message) when `make artifacts` has not been run.

use std::collections::BTreeMap;
use std::sync::Arc;

use ssm_peft::coordinator::Pipeline;
use ssm_peft::eval::{
    beam_search, AdapterDelta, AdapterStepDecode, DecodeCore, LoraOp, PinnedAdapter,
    SparseOffset,
};
use ssm_peft::manifest::{Manifest, PeftMeta};
use ssm_peft::runtime::Engine;
use ssm_peft::serve::{LaneModel, Request, Response, Scheduler, ServeFactory, ServeModel};
use ssm_peft::suite::PeftMethod;
use ssm_peft::tensor::{Rng, Tensor};

fn setup() -> Option<(Engine, Manifest)> {
    let dir = ssm_peft::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
        return None;
    }
    let e = Engine::cpu().expect("pjrt cpu");
    let m = Manifest::load(dir).expect("manifest");
    Some((e, m))
}

/// A non-trivial synthetic trained adapter against the staged base: one
/// rank-2 LoRA pair on the first 2-D weight plus sparse trained-value
/// replacements on a second parameter — the same shape a checkpointed
/// SDT+LoRA adapter distills to, but deterministic from `seed` so two
/// calls give two distinct adapters.
fn test_delta(base: &BTreeMap<String, Tensor>, seed: u64) -> Arc<AdapterDelta> {
    let mut rng = Rng::new(seed);
    let (target, t) = base
        .iter()
        .find(|(k, t)| {
            t.shape.len() == 2 && t.shape[0] >= 4 && t.shape[1] >= 4 && !k.ends_with(".h0")
        })
        .map(|(k, t)| ((*k).clone(), t))
        .expect("base has a 2-D weight to adapt");
    let r = 2usize;
    let mut mk = |shape: &[usize]| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal() * 0.02).collect())
    };
    let lora = vec![LoraOp {
        target: target.clone(),
        a: mk(&[t.shape[0], r]),
        b: mk(&[r, t.shape[1]]),
    }];
    let (sk, st) = base
        .iter()
        .find(|(k, t)| **k != target && !k.ends_with(".h0") && t.numel() >= 8)
        .map(|(k, t)| ((*k).clone(), t))
        .expect("base has a second parameter");
    let stride = (st.numel() / 8).max(1);
    let idx: Vec<usize> = (0..st.numel()).step_by(stride).take(8).collect();
    let val: Vec<f32> = idx.iter().map(|&i| st.data[i] + 0.25 + rng.uniform()).collect();
    Arc::new(AdapterDelta {
        meta: PeftMeta {
            method: PeftMethod::Sdt,
            rank: r,
            alpha: r,
            targets: Vec::new(),
            n_tokens: 0,
        },
        lora,
        sparse: vec![SparseOffset { param: sk, idx, val }],
        h0: BTreeMap::new(),
    })
}

/// Run `reqs` through a fresh scheduler to completion, sorted by id.
fn drive(factory: ServeFactory, reqs: Vec<Request>) -> Vec<Response> {
    let mut sched = Scheduler::new(factory, 4);
    for r in reqs {
        sched.submit(r);
    }
    let mut out = sched.run_to_completion();
    out.sort_by_key(|r| r.id);
    out
}

/// Solo reference: the same request decoded on a dedicated merged lane
/// (whole-model copy with the delta applied).
fn solo_merged(e: &Engine, m: &Manifest, base: &BTreeMap<String, Tensor>,
               delta: &AdapterDelta, req: Request) -> Response {
    let merged = delta.apply(base).expect("delta applies to base");
    let core = DecodeCore::new(e, m, "mamba1_xs_full", &merged).expect("merged core");
    let model: Arc<dyn ssm_peft::eval::StepDecode> = Arc::new(core);
    let factory: ServeFactory = Box::new(move |_: &str| {
        Ok(ServeModel::Merged(LaneModel { model: model.clone(), h0: None }))
    });
    drive(factory, vec![req]).pop().expect("one response")
}

fn req(id: u64, adapter: &str, prompt: &[u8], max_new: usize) -> Request {
    Request {
        id,
        adapter: adapter.into(),
        prompt: prompt.to_vec(),
        max_new,
        stop_byte: b'\n',
        beam: 1,
        deadline: 0,
        session: None,
    }
}

#[test]
fn mixed_adapter_batch_matches_merged_lanes_bytewise() {
    // the tentpole pin: two different adapters (plus the plain base)
    // decoding in ONE shared batch, with a third request admitted
    // mid-stream, produce byte-identical outputs to dedicated merged
    // lanes serving one adapter each
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let base = p.pretrained("mamba1_xs", 60, 0).expect("staged base");
    let core = match DecodeCore::new_unmerged(e, m, "mamba1_xs_full", base.clone()) {
        Ok(c) => Arc::new(c),
        Err(err) => {
            eprintln!("SKIP: unmerged decode unavailable: {err:#}");
            return;
        }
    };
    eprintln!(
        "unmerged path: {}",
        if core.has_adapter_artifact() { "decode_adapters artifact" }
        else { "grouped host fallback" }
    );
    let d1 = test_delta(&base, 11);
    let d2 = test_delta(&base, 22);

    let reqs = [
        req(1, "a1", b"name=ann|team=red", 8),
        req(2, "a2", b"cat dog fish", 8),
        req(3, "a1", b"name=bob|team=blue", 6),
    ];
    let want: Vec<Response> = vec![
        solo_merged(e, m, &base, &d1, reqs[0].clone()),
        solo_merged(e, m, &base, &d2, reqs[1].clone()),
        solo_merged(e, m, &base, &d1, reqs[2].clone()),
    ];

    let (d1c, d2c, core_c) = (d1.clone(), d2.clone(), core.clone());
    let factory: ServeFactory = Box::new(move |a: &str| {
        let delta = match a {
            "a1" => Some(d1c.clone()),
            "a2" => Some(d2c.clone()),
            _ => None,
        };
        let model: Arc<dyn AdapterStepDecode> = core_c.clone();
        Ok(ServeModel::Shared { model, delta, h0: None })
    });
    let mut sched = Scheduler::new(factory, 4);
    sched.submit(reqs[0].clone());
    sched.submit(reqs[1].clone());
    sched.tick();
    if core.arch_b() >= 2 {
        assert_eq!(sched.active(), 2, "adapters share one batch");
    }
    sched.tick();
    sched.submit(reqs[2].clone()); // mid-stream admission into a live batch
    let mut got = sched.run_to_completion();
    got.sort_by_key(|r| r.id);

    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert!(g.error.is_none(), "request {} failed: {:?}", g.id, g.error);
        assert_eq!(
            g.output, w.output,
            "request {}: unmerged row bytes != merged-lane bytes", g.id
        );
        assert_eq!(g.steps, w.steps, "request {}: step accounting drifted", g.id);
    }
    // the whole point of the shared batch: fewer dispatches than the sum
    // of dedicated lanes (gate on real concurrency being possible)
    if core.arch_b() >= 3 {
        let solo_total: u64 = want.iter().map(|r| r.steps).sum();
        assert!(
            sched.decode_steps < solo_total,
            "shared batch used {} dispatches, dedicated lanes {}",
            sched.decode_steps, solo_total
        );
    }
}

#[test]
fn base_rows_in_mixed_batch_match_plain_base() {
    // a `None` delta row through the unmerged path is the unmodified base
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let base = p.pretrained("mamba1_xs", 60, 0).expect("staged base");
    let core = match DecodeCore::new_unmerged(e, m, "mamba1_xs_full", base.clone()) {
        Ok(c) => Arc::new(c),
        Err(err) => {
            eprintln!("SKIP: unmerged decode unavailable: {err:#}");
            return;
        }
    };
    let d1 = test_delta(&base, 33);
    let r_base = req(1, "base", b"name=eve|team=green", 8);
    let r_ad = req(2, "a1", b"name=eve|team=green", 8);

    let plain = DecodeCore::new(e, m, "mamba1_xs_full", &base).expect("base core");
    let model: Arc<dyn ssm_peft::eval::StepDecode> = Arc::new(plain);
    let base_factory: ServeFactory = Box::new(move |_: &str| {
        Ok(ServeModel::Merged(LaneModel { model: model.clone(), h0: None }))
    });
    let want_base = drive(base_factory, vec![r_base.clone()]).pop().unwrap();
    let want_ad = solo_merged(e, m, &base, &d1, r_ad.clone());

    let (d1c, core_c) = (d1.clone(), core.clone());
    let factory: ServeFactory = Box::new(move |a: &str| {
        let model: Arc<dyn AdapterStepDecode> = core_c.clone();
        let delta = (a == "a1").then(|| d1c.clone());
        Ok(ServeModel::Shared { model, delta, h0: None })
    });
    let got = drive(factory, vec![r_base, r_ad]);
    assert_eq!(got[0].output, want_base.output, "base row perturbed by neighbor delta");
    assert_eq!(got[1].output, want_ad.output, "adapter row perturbed by base neighbor");
    // same prompt, different adapters: outputs should differ, or the
    // synthetic delta was a no-op and this harness pins nothing
    assert_ne!(got[0].output, got[1].output, "test delta did not change decode");
}

#[test]
fn pinned_adapter_beam_matches_merged_beam() {
    // beam search runs the unmerged core through PinnedAdapter (every row
    // one delta); bytes must match beam over the merged whole-model copy
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let base = p.pretrained("mamba1_xs", 60, 0).expect("staged base");
    let core = match DecodeCore::new_unmerged(e, m, "mamba1_xs_full", base.clone()) {
        Ok(c) => Arc::new(c),
        Err(err) => {
            eprintln!("SKIP: unmerged decode unavailable: {err:#}");
            return;
        }
    };
    let d1 = test_delta(&base, 44);
    let prompt = b"name=ann|team=red".to_vec();
    let merged = d1.apply(&base).expect("delta applies");
    let mcore = DecodeCore::new(e, m, "mamba1_xs_full", &merged).expect("merged core");
    let want = beam_search(&mcore, &prompt, 3, 10, b'\n', None).expect("merged beam");
    let pinned = PinnedAdapter::new(core, Some(d1));
    let got = beam_search(&pinned, &prompt, 3, 10, b'\n', None).expect("pinned beam");
    assert_eq!(got, want, "unmerged beam bytes != merged beam bytes");
}
