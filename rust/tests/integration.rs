//! Integration tests over the real AOT artifacts: runtime → trainer → PEFT
//! engine → eval. These require `make artifacts` to have been run; they
//! skip (with a message) when the artifacts directory is absent so
//! `cargo test` stays green on a fresh checkout.

use ssm_peft::config::ExperimentConfig;
use ssm_peft::coordinator::Pipeline;
use ssm_peft::data::{make_lm_batch, tasks, BatchIter};
use ssm_peft::eval::{
    beam_search, greedy_decode, plan_chunks, AdapterStepDecode, DecodeCore, DecodeState,
    Generator, StateDims, StepDecode,
};
use ssm_peft::tensor::{IntTensor, Tensor};
use ssm_peft::manifest::Manifest;
use ssm_peft::peft::{select_dimensions, Budget, SdtConfig};
use ssm_peft::runtime::Engine;
use ssm_peft::serve::{
    AdapterRegistry, LaneModel, ManifestSource, Request, Scheduler, ServeFactory,
    ServeModel,
};
use ssm_peft::suite::{JsonlSink, PeftMethod, Suite, VariantId};
use ssm_peft::tensor::Rng;
use ssm_peft::train::{checkpoint, TrainConfig, Trainer};

/// Per-test setup: each test builds its own engine (tests run on separate
/// threads and an `Engine` is cheap); the XLA compile cache inside
/// `Engine` still amortizes within a test, and the suite tests share one
/// engine across their worker threads (`Engine` is `Sync` — see
/// runtime/mod.rs safety notes).
fn setup() -> Option<(Engine, Manifest)> {
    let dir = ssm_peft::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
        return None;
    }
    let e = Engine::cpu().expect("pjrt cpu");
    let m = Manifest::load(dir).expect("manifest");
    Some((e, m))
}

#[test]
fn manifest_has_all_peft_families() {
    let Some((_, ref m)) = setup() else { return };
    for needle in ["lora_lin", "dora_lin", "bitfit", "prompt", "prefix",
                   "initstate", "addscan", "sdt", "sdtlora", "full"] {
        assert!(
            m.variants.keys().any(|k| k.ends_with(needle)),
            "missing PEFT family {needle}"
        );
    }
    // paper's parameter-budget claim: sparse methods are tiny
    let v = m.variant("mamba1_xs_bitfit").unwrap();
    assert!(v.train_fraction() < 0.01, "bitfit should be <1%");
}

#[test]
fn lm_training_reduces_loss() {
    let Some((ref e, ref m)) = setup() else { return };
    let cfg = TrainConfig { lr: 3e-3, schedule_total: 30, ..Default::default() };
    let mut tr = Trainer::new(e, m, "mamba1_xs_full", &cfg).unwrap();
    let corpus = tasks::pretrain_corpus(0, 1 << 14);
    let mut rng = Rng::new(0);
    let mut first = 0.0;
    let mut last = 0.0;
    for s in 0..30 {
        let b = make_lm_batch(&corpus, &mut rng, tr.variant.batch_b, tr.variant.batch_l);
        let loss = tr.step(&b).unwrap();
        if s == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first * 0.6, "loss {first} -> {last} did not drop enough");
}

#[test]
fn masked_entries_never_change() {
    let Some((ref e, ref m)) = setup() else { return };
    let cfg = TrainConfig { lr: 1e-2, schedule_total: 10, ..Default::default() };
    let mut tr = Trainer::new(e, m, "mamba1_xs_sdt", &cfg).unwrap();
    // mask everything except one entry of the first tensor
    let mut masks = vec![];
    for (i, p) in tr.variant.train_params.iter().enumerate() {
        let mut mvec = vec![0.0f32; p.numel];
        if i == 0 {
            mvec[0] = 1.0;
        }
        masks.push(Some(mvec));
    }
    tr.set_masks(ssm_peft::peft::Masks { masks });
    let before = tr.snapshot_train();
    let ds = tasks::by_name("glue/rte", 0, 64).unwrap();
    let mut rng = Rng::new(1);
    let it = BatchIter::new(&ds.train, &mut rng, tr.variant.batch_b, tr.variant.batch_l);
    for (b, _) in it.take(3) {
        tr.step(&b).unwrap();
    }
    let after = tr.snapshot_train();
    for (i, (b, a)) in before.iter().zip(&after).enumerate() {
        for (j, (&x, &y)) in b.data.iter().zip(&a.data).enumerate() {
            if i == 0 && j == 0 {
                assert_ne!(x, y, "the one unmasked entry should move");
            } else {
                assert_eq!(x, y, "masked entry ({i},{j}) moved");
            }
        }
    }
}

#[test]
fn sdt_selection_budget_under_one_percent() {
    let Some((ref e, ref m)) = setup() else { return };
    let cfg = TrainConfig { lr: 1e-2, schedule_total: 10, ..Default::default() };
    let mut tr = Trainer::new(e, m, "mamba1_xs_sdt", &cfg).unwrap();
    let before = tr.train_map();
    let ds = tasks::by_name("glue/rte", 0, 64).unwrap();
    let mut rng = Rng::new(2);
    let it = BatchIter::new(&ds.train, &mut rng, tr.variant.batch_b, tr.variant.batch_l);
    for (b, _) in it.take(4) {
        tr.step(&b).unwrap();
    }
    let after = tr.train_map();
    let sdt = SdtConfig { channel_freeze: 0.99, state_freeze: 0.9, ..Default::default() };
    let (masks, sels) = select_dimensions(&tr.variant, &before, &after, &sdt);
    let budget = Budget::of(&tr.variant, Some(&masks));
    assert!(budget.percent() < 1.0, "SDT budget {}% should be <1%", budget.percent());
    assert_eq!(sels.len(), tr.variant.arch.n_layer);
    for s in &sels {
        assert!(!s.trainable_channels.is_empty());
    }
}

#[test]
fn decode_greedy_emits_bytes_and_respects_stop() {
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let base = p.pretrained("mamba1_xs", 150, 0).unwrap();
    let gen = Generator::new(e, m, "mamba1_xs_full", &base).unwrap();
    let outs = gen
        .greedy(&[b"name=ann|team=red".to_vec(), b"cat dog".to_vec()], 24, b'\n', None)
        .unwrap();
    assert_eq!(outs.len(), 2);
    for o in &outs {
        assert!(o.len() <= 24);
        assert!(o.iter().all(|&b| b != b'\n'));
    }
}

#[test]
fn beam_matches_or_beats_greedy_logprob_shape() {
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let base = p.pretrained("mamba1_xs", 150, 0).unwrap();
    let gen = Generator::new(e, m, "mamba1_xs_full", &base).unwrap();
    let beam = gen.beam(b"name=ann", 4, 16, b'\n', None).unwrap();
    assert!(beam.len() <= 16);
}

#[test]
fn regression_variant_runs_and_fits() {
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let (xs, ys) = p.synthetic_s4_data(0, 3, 200).unwrap();
    let cfg = TrainConfig { lr: 2e-3, schedule_total: 30, ..Default::default() };
    let mut tr = Trainer::new(e, m, "s4reg_full", &cfg).unwrap();
    let mask = ssm_peft::tensor::Tensor::from_vec(
        &[tr.variant.batch_b, 200],
        vec![1.0; tr.variant.batch_b * 200],
    );
    let first = tr.step_reg(&xs[0], &ys[0], &mask).unwrap();
    let mut last = first;
    for i in 0..20 {
        last = tr.step_reg(&xs[i % 3], &ys[i % 3], &mask).unwrap();
    }
    assert!(last < first, "regression loss should drop: {first} -> {last}");
}

#[test]
fn full_pipeline_classification_beats_chance_after_training() {
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let mut cfg = ExperimentConfig::default();
    cfg.variant = "mamba1_xs_lora_lin".into();
    cfg.dataset = "glue/qnli".into();
    cfg.n_train = 256;
    cfg.epochs = 4;
    cfg.max_batches_per_epoch = 16;
    cfg.pretrain_steps = 150;
    cfg.lr_grid = vec![3e-3];
    let out = p.finetune(&cfg).unwrap();
    // binary task, 96 test examples: > 0.58 is statistically above chance
    assert!(out.metric > 0.58, "qnli acc {} not above chance", out.metric);
    assert!(out.budget_pct < 10.0);
}

#[test]
fn checkpoint_pipeline_roundtrip() {
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let base = p.pretrained("mamba1_xs", 150, 0).unwrap();
    let path = std::env::temp_dir().join(format!("it_ckpt_{}.bin", std::process::id()));
    checkpoint::save(&base, &path).unwrap();
    let back = checkpoint::load(&path).unwrap();
    assert_eq!(base.len(), back.len());
    assert_eq!(base["embed"], back["embed"]);
    std::fs::remove_file(path).ok();
}

#[test]
fn variant_ids_roundtrip_against_real_manifest() {
    // the typed parser must agree with the manifest for EVERY exported
    // variant: name round-trips and the parsed method matches the peft
    // block python aot.py wrote.
    let Some((_, ref m)) = setup() else { return };
    for (name, v) in &m.variants {
        let vid = VariantId::parse(name)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(vid.name(), *name, "{name}: round-trip");
        assert_eq!(vid.method, v.peft.method, "{name}: method mismatch");
        assert!(
            m.variants.contains_key(&vid.decode_variant()),
            "{name}: decode variant {} missing", vid.decode_variant()
        );
    }
    let vid = VariantId::parse("mamba1_xs_sdtlora").unwrap();
    assert_eq!(vid.arch, "mamba1_xs");
    assert_eq!(vid.method, PeftMethod::SdtLora);
    assert_eq!(VariantId::parse("s4reg_t_full").unwrap().arch, "s4reg_t");
    assert!(VariantId::parse("nonexistent_arch_x").is_err());
}

#[test]
fn suite_runs_cells_on_two_workers() {
    // the acceptance smoke test: a 2-cell grid on 2 workers produces one
    // record per cell, deterministic per-cell seeds, and a JSONL stream.
    let Some((ref e, ref m)) = setup() else { return };
    let mk = || {
        let mut t = ExperimentConfig::default();
        t.n_train = 64;
        t.epochs = 1;
        t.max_batches_per_epoch = 3;
        t.pretrain_steps = 60;
        t.lr_grid = vec![3e-3];
        Suite::new(e, m)
            .named("it_suite_smoke")
            .template(t)
            .grid(&["mamba1_xs_lora_lin"], &["glue/rte", "glue/sst2"])
    };
    let suite = mk();
    let seeds: Vec<u64> = suite.plan.cells.iter().map(|c| c.seed).collect();
    assert_eq!(seeds, mk().plan.cells.iter().map(|c| c.seed).collect::<Vec<u64>>(),
               "cell seeds must be deterministic");
    assert_ne!(seeds[0], seeds[1], "cells get distinct seeds");

    let records = suite.run(2).unwrap();
    assert_eq!(records.len(), 2, "one record per cell");
    for (r, s) in records.iter().zip(&seeds) {
        assert!(r.ok(), "cell {}/{} failed: {:?}", r.variant, r.dataset, r.error);
        assert_eq!(r.seed, *s, "record carries the planned seed");
        assert!(r.metric > 0.0);
        assert!(!r.git.is_empty());
    }
    let jsonl = ssm_peft::results_dir().join("it_suite_smoke.jsonl");
    let loaded = JsonlSink::load("it_suite_smoke");
    assert_eq!(loaded.len(), 2, "JSONL stream holds both records");
    std::fs::remove_file(jsonl).ok();
}

#[test]
fn suite_resume_reuses_finished_cells() {
    let Some((ref e, ref m)) = setup() else { return };
    let mk = |resume| {
        let mut t = ExperimentConfig::default();
        t.n_train = 64;
        t.epochs = 1;
        t.max_batches_per_epoch = 3;
        t.pretrain_steps = 60;
        t.lr_grid = vec![3e-3];
        Suite::new(e, m)
            .named("it_suite_resume")
            .template(t)
            .resume(resume)
            .cell("mamba1_xs_bitfit", "glue/rte")
    };
    let first = mk(false).run(1).unwrap();
    assert!(first[0].ok());
    let again = mk(true).run(2).unwrap();
    assert_eq!(again.len(), 1);
    // resumed record is byte-identical in the fields that matter
    assert_eq!(again[0].metric, first[0].metric);
    assert_eq!(again[0].seed, first[0].seed);
    // and the file was not duplicated
    assert_eq!(JsonlSink::load("it_suite_resume").len(), 1);
    std::fs::remove_file(ssm_peft::results_dir().join("it_suite_resume.jsonl")).ok();
}

#[test]
fn serve_two_adapters_from_one_staged_base() {
    // the serving acceptance path at the library level: one staged base,
    // two different adapters, two requests answered concurrently by the
    // continuous-batching scheduler over the REAL decode artifacts
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let base = p.pretrained("mamba1_xs", 60, 0).unwrap();
    let source = ManifestSource {
        manifest: m,
        base_arch: "mamba1_xs".into(),
        base: base.clone(),
        adapter_dir: None,
    };
    let registry = AdapterRegistry::new(source, 2);
    // one shared unmerged core serves every delta-representable adapter;
    // anything else falls back to a per-adapter merged lane
    let shared = DecodeCore::new_unmerged(e, m, "mamba1_xs_full", base.clone())
        .ok()
        .map(std::sync::Arc::new);
    let factory: ServeFactory = Box::new(|a: &str| {
        let ad = registry.get(a)?;
        if let (Some(core), Some(delta)) = (&shared, &ad.delta) {
            registry.pin(a);
            let model: std::sync::Arc<dyn AdapterStepDecode> = core.clone();
            return Ok(ServeModel::Shared {
                model,
                delta: Some(delta.clone()),
                h0: ad.h0.clone(),
            });
        }
        let params = registry.load_merged(a)?;
        let core = DecodeCore::new(e, m, &ad.decode_variant, &params)?;
        Ok(ServeModel::Merged(LaneModel {
            model: std::sync::Arc::new(core),
            h0: ad.h0.clone(),
        }))
    });
    let mut sched = Scheduler::new(factory, 4);
    sched.on_release(Box::new(|a: &str| registry.unpin(a)));
    sched.submit(Request {
        id: 1,
        adapter: "mamba1_xs_lora_lin".into(),
        prompt: b"name=ann|team=red".to_vec(),
        max_new: 12,
        stop_byte: b'\n',
        beam: 1,
        deadline: 0,
        session: None,
    });
    sched.submit(Request {
        id: 2,
        adapter: "mamba1_xs_bitfit".into(),
        prompt: b"cat dog".to_vec(),
        max_new: 12,
        stop_byte: b'\n',
        beam: 1,
        deadline: 0,
        session: None,
    });
    sched.tick();
    assert_eq!(sched.active(), 2, "both adapters decode concurrently");
    let mut resps = sched.run_to_completion();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), 2);
    for r in &resps {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert!(r.output.len() <= 12);
        assert!(r.output.iter().all(|&b| b != b'\n'));
        assert!(r.steps > 0);
    }
    assert_eq!(resps[0].adapter, "mamba1_xs_lora_lin");
    assert_eq!(resps[1].adapter, "mamba1_xs_bitfit");
    let st = registry.stats();
    assert_eq!(st.misses, 2, "each adapter materialized once");
    // a repeat request hits the cache, not a re-merge
    sched.submit(Request {
        id: 3,
        adapter: "mamba1_xs_bitfit".into(),
        prompt: b"cat dog".to_vec(),
        max_new: 4,
        stop_byte: b'\n',
        beam: 1,
        deadline: 0,
        session: None,
    });
    let more = sched.run_to_completion();
    assert_eq!(more.len(), 1);
    assert!(more[0].error.is_none());
    // the repeat admission hits the delta cache (or the kept merged lane);
    // either way, misses must not grow
    assert_eq!(registry.stats().misses, 2);
}

/// A [`DecodeCore`] with its chunked prefill masked off: the stepwise
/// prompt-ingestion baseline (inherits `chunk_prefill() -> None`).
struct StepwiseOnly(DecodeCore);

impl StepDecode for StepwiseOnly {
    fn arch_b(&self) -> usize {
        self.0.arch_b()
    }
    fn dims(&self) -> StateDims {
        self.0.dims()
    }
    fn step(&self, tokens: &IntTensor, state: &mut DecodeState)
        -> ssm_peft::error::Result<Tensor> {
        self.0.step(tokens, state)
    }
}

#[test]
fn chunked_prefill_matches_stepwise_on_real_executables() {
    // acceptance: greedy and beam through the REAL prefill executables
    // produce the same bytes as pure token-by-token stepping, and the
    // dispatch count drops by (covered - plan) per pass
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let base = p.pretrained("mamba1_xs", 150, 0).unwrap();
    let core = DecodeCore::new(e, m, "mamba1_xs_full", &base).unwrap();
    if core.prefill_widths().is_empty() {
        eprintln!("SKIP: artifacts predate prefill; re-run `python -m compile.aot`");
        return;
    }
    let stepwise = StepwiseOnly(DecodeCore::new(e, m, "mamba1_xs_full", &base).unwrap());
    let prompts = vec![
        b"name=ann|team=red|city=oslo|role=lead".to_vec(),
        b"name=bob|team=blue|city=rome|role=dev".to_vec(),
    ];
    let want = greedy_decode(&stepwise, &prompts, 16, b'\n', None).unwrap();
    let d0 = core.dispatch_count();
    let got = greedy_decode(&core, &prompts, 16, b'\n', None).unwrap();
    assert_eq!(got, want, "chunked greedy differs from stepwise");
    let chunked_d = core.dispatch_count() - d0;
    let stepwise_d = stepwise.0.dispatch_count();
    let min_prompt = prompts.iter().map(Vec::len).min().unwrap();
    let (plan, _) = plan_chunks(core.prefill_widths(), min_prompt);
    let covered: u64 = plan.iter().sum::<usize>() as u64;
    assert_eq!(
        chunked_d,
        stepwise_d - covered + plan.len() as u64,
        "each covered token replaces one dispatch; each chunk adds one"
    );

    let beam_want = beam_search(&stepwise, &prompts[0], 4, 12, b'\n', None).unwrap();
    let beam_got = beam_search(&core, &prompts[0], 4, 12, b'\n', None).unwrap();
    assert_eq!(beam_got, beam_want, "chunked beam differs from stepwise");
}

#[test]
fn serve_prefill_then_admit_on_real_executables() {
    // the serving acceptance path: a request admitted through out-of-band
    // chunked prefill generates the same bytes as through stepwise
    // ingestion, and the scheduler reports the chunk dispatches
    let Some((ref e, ref m)) = setup() else { return };
    let p = Pipeline::new(e, m);
    let base = p.pretrained("mamba1_xs", 60, 0).unwrap();
    let core = DecodeCore::new(e, m, "mamba1_xs_full", &base).unwrap();
    if core.prefill_widths().is_empty() {
        eprintln!("SKIP: artifacts predate prefill; re-run `python -m compile.aot`");
        return;
    }
    let widths = core.prefill_widths().to_vec();
    let prompt = b"name=ann|team=red|city=oslo|role=lead".to_vec();
    let run = |model: std::sync::Arc<dyn StepDecode>| {
        let factory: ServeFactory = Box::new(move |_adapter: &str| {
            Ok(ServeModel::Merged(LaneModel { model: model.clone(), h0: None }))
        });
        let mut sched = Scheduler::new(factory, 2);
        sched.submit(Request {
            id: 1,
            adapter: "mamba1_xs_full".into(),
            prompt: prompt.clone(),
            max_new: 12,
            stop_byte: b'\n',
            beam: 1,
            deadline: 0,
            session: None,
        });
        let resp = sched.run_to_completion().pop().unwrap();
        (resp, sched.prefill_dispatches, sched.prefill_tokens)
    };
    let stepwise = StepwiseOnly(DecodeCore::new(e, m, "mamba1_xs_full", &base).unwrap());
    let (want, d_plain, _) = run(std::sync::Arc::new(stepwise));
    assert_eq!(d_plain, 0, "no chunk support, no prefill");
    let (got, d_chunked, covered) = run(std::sync::Arc::new(core));
    assert!(got.error.is_none(), "{:?}", got.error);
    assert_eq!(got.output, want.output, "prefilled admission changed bytes");
    assert_eq!(got.steps, want.steps, "consumed-token accounting unchanged");
    let (plan, _) = plan_chunks(&widths, prompt.len());
    assert_eq!(d_chunked, plan.len() as u64);
    assert_eq!(covered, plan.iter().sum::<usize>() as u64);
}

#[test]
fn lora_merge_preserves_fwd_logits() {
    // adapter-forward == merged-forward, through the REAL artifacts:
    // run fwd on lora variant, then merge into base names and run the
    // full variant's fwd.
    let Some((ref e, ref m)) = setup() else { return };
    let cfg = TrainConfig { lr: 1e-2, schedule_total: 6, ..Default::default() };
    let mut tr = Trainer::new(e, m, "mamba1_xs_lora_lin", &cfg).unwrap();
    // train a few steps so adapters are non-trivial
    let ds = tasks::by_name("glue/rte", 0, 64).unwrap();
    let mut rng = Rng::new(3);
    let it = BatchIter::new(&ds.train, &mut rng, tr.variant.batch_b, tr.variant.batch_l);
    let mut batch0 = None;
    for (b, _) in it.take(4) {
        tr.step(&b).unwrap();
        batch0.get_or_insert(b);
    }
    let batch = batch0.unwrap();
    let logits_adapter = tr.logits(&batch).unwrap();

    let mut merged = tr.params_map();
    ssm_peft::peft::merge_lora(&mut merged, &tr.variant.peft);
    let mut tr_full = Trainer::new(e, m, "mamba1_xs_full", &cfg).unwrap();
    tr_full.load_base(&merged);
    let logits_merged = tr_full.logits(&batch).unwrap();
    let max_diff = logits_adapter
        .data
        .iter()
        .zip(&logits_merged.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-3, "merge drift {max_diff}");
}
