//! Fused-arena optimizer equivalence suite (no artifacts needed).
//!
//! The legacy three-pass pipeline (`Masks::apply` → `clip_global_norm` →
//! `AdamW::step` over `Vec<Tensor>` leaves) is the reference oracle; the
//! fused `ParamArena` pass must match it to ≤1e-6 across randomized
//! shapes, masks, clipping regimes and worker counts — and must be
//! bitwise-deterministic in the worker count.

use ssm_peft::optim::{
    clip_global_norm, AdamW, FusedAdamW, FusedSgd, MaskPlan, ParamArena, Sgd,
};
use ssm_peft::peft::Masks;
use ssm_peft::tensor::{Rng, Tensor};

/// Random leaf set: `n_leaves` tensors with random small shapes.
fn random_leaves(rng: &mut Rng, n_leaves: usize, max_side: usize) -> Vec<Tensor> {
    (0..n_leaves)
        .map(|_| {
            let shape = match rng.below(3) {
                0 => vec![1 + rng.below(max_side)],
                1 => vec![1 + rng.below(max_side), 1 + rng.below(max_side)],
                _ => vec![1 + rng.below(8), 1 + rng.below(8), 1 + rng.below(8)],
            };
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            Tensor::from_vec(&shape, data)
        })
        .collect()
}

fn random_grads(rng: &mut Rng, leaves: &[Tensor], scale: f32) -> Vec<Tensor> {
    leaves
        .iter()
        .map(|t| {
            let data: Vec<f32> = (0..t.numel()).map(|_| rng.normal() * scale).collect();
            Tensor::from_vec(&t.shape, data)
        })
        .collect()
}

/// Random masks: per leaf, None / sparse-binary / dense-float.
fn random_masks(rng: &mut Rng, leaves: &[Tensor]) -> Masks {
    let masks = leaves
        .iter()
        .map(|t| match rng.below(3) {
            0 => None,
            1 => Some(
                (0..t.numel())
                    .map(|_| if rng.uniform() < 0.05 { 1.0 } else { 0.0 })
                    .collect(),
            ),
            // non-binary mask exercises the dense fallback
            _ => Some(
                (0..t.numel())
                    .map(|_| if rng.uniform() < 0.5 { rng.uniform() } else { 0.0 })
                    .collect(),
            ),
        })
        .collect();
    Masks { masks }
}

/// Drive legacy and fused for `steps` steps with identical inputs; return
/// (legacy params, fused params).
fn run_both(
    leaves: &[Tensor],
    masks: &Masks,
    steps: usize,
    max_norm: f32,
    workers: usize,
    grad_seed: u64,
    grad_scale: f32,
) -> (Vec<Tensor>, Vec<Tensor>) {
    // legacy reference
    let mut lp = leaves.to_vec();
    let mut lopt = AdamW::new(&lp);
    let mut lrng = Rng::new(grad_seed);
    for s in 0..steps {
        let mut g = random_grads(&mut lrng, leaves, grad_scale);
        masks.apply(&mut g);
        clip_global_norm(&mut g, max_norm);
        lopt.step(&mut lp, &g, 1e-3 * (s + 1) as f32);
    }
    // fused
    let mut arena = ParamArena::pack(leaves);
    let mut fopt = FusedAdamW::new(&arena);
    let (m, v) = (fopt.moments().0.to_vec(), fopt.moments().1.to_vec());
    let plan = MaskPlan::compile(&masks.masks, &arena, &m, &v);
    let mut frng = Rng::new(grad_seed);
    for s in 0..steps {
        let g = ParamArena::pack(&random_grads(&mut frng, leaves, grad_scale));
        fopt.step(&mut arena, g.data(), &plan, 1e-3 * (s + 1) as f32, max_norm, workers);
    }
    (lp, arena.unpack())
}

fn assert_close(a: &[Tensor], b: &[Tensor], tol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.shape, y.shape, "{ctx}: leaf {i} shape");
        for (j, (&xa, &xb)) in x.data.iter().zip(&y.data).enumerate() {
            assert!(
                (xa - xb).abs() <= tol,
                "{ctx}: leaf {i} entry {j}: legacy {xa} fused {xb}"
            );
        }
    }
}

#[test]
fn fused_matches_legacy_on_randomized_shapes_and_masks() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed * 1000 + 1);
        let leaves = random_leaves(&mut rng, 2 + seed as usize % 4, 40);
        let masks = random_masks(&mut rng, &leaves);
        // small max_norm so clipping actually engages on some steps
        let (lp, fp) = run_both(&leaves, &masks, 5, 0.5, 1, seed ^ 0x9e37, 1.0);
        assert_close(&lp, &fp, 1e-6, &format!("seed {seed}"));
    }
}

#[test]
fn fused_matches_legacy_without_clipping_engaged() {
    let mut rng = Rng::new(77);
    let leaves = random_leaves(&mut rng, 3, 30);
    let masks = Masks::none(leaves.len());
    // tiny grads: norm stays below the threshold, scale == 1.0
    let (lp, fp) = run_both(&leaves, &masks, 4, 1e6, 1, 123, 1e-3);
    assert_close(&lp, &fp, 1e-6, "no-clip");
}

#[test]
fn sparse_index_path_matches_dense_reference() {
    // 1%-active binary masks: the plan must compile to Sparse and still
    // match the dense legacy walk exactly
    let mut rng = Rng::new(5);
    let leaves = vec![
        Tensor::from_vec(&[64, 32], (0..2048).map(|i| (i as f32).sin()).collect()),
        Tensor::from_vec(&[512], (0..512).map(|i| (i as f32).cos()).collect()),
    ];
    let masks = Masks {
        masks: leaves
            .iter()
            .map(|t| {
                Some(
                    (0..t.numel())
                        .map(|j| if j % 97 == 0 { 1.0 } else { 0.0 })
                        .collect(),
                )
            })
            .collect(),
    };
    let arena = ParamArena::pack(&leaves);
    let opt = FusedAdamW::new(&arena);
    let (m, v) = opt.moments();
    let plan = MaskPlan::compile(&masks.masks, &arena, m, v);
    assert!(plan.any_sparse(), "1%-active binary masks must compile sparse");
    let (lp, fp) = run_both(&leaves, &masks, 6, 0.25, 1, rng.next_u64(), 1.0);
    assert_close(&lp, &fp, 1e-6, "sparse");
    // masked entries must be EXACTLY untouched in both implementations
    for leaf in 0..leaves.len() {
        for j in 0..leaves[leaf].numel() {
            if j % 97 != 0 {
                assert_eq!(
                    leaves[leaf].data[j], fp[leaf].data[j],
                    "masked entry moved in fused (leaf {leaf} entry {j})"
                );
                assert_eq!(leaves[leaf].data[j], lp[leaf].data[j]);
            }
        }
    }
}

#[test]
fn arena_pack_unpack_roundtrip_randomized() {
    for seed in 0..4u64 {
        let mut rng = Rng::new(seed + 400);
        let leaves = random_leaves(&mut rng, 1 + seed as usize, 25);
        let arena = ParamArena::pack(&leaves);
        assert_eq!(arena.unpack(), leaves, "seed {seed}");
        assert_eq!(arena.len(), leaves.iter().map(Tensor::numel).sum::<usize>());
    }
}

#[test]
fn worker_count_does_not_change_the_result_bitwise() {
    // big enough to clear the inline-execution threshold and span many
    // chunks, so 4 workers genuinely run the scoped pool
    let mut rng = Rng::new(9);
    let leaves = vec![
        Tensor::from_vec(&[100_000], (0..100_000).map(|_| rng.normal()).collect()),
        Tensor::from_vec(&[300, 70], (0..21_000).map(|_| rng.normal()).collect()),
    ];
    let masks = Masks::none(leaves.len());
    let (_, p1) = run_both(&leaves, &masks, 3, 0.5, 1, 31337, 1.0);
    let (_, p4) = run_both(&leaves, &masks, 3, 0.5, 4, 31337, 1.0);
    for (i, (a, b)) in p1.iter().zip(&p4).enumerate() {
        for (j, (&xa, &xb)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(
                xa.to_bits(),
                xb.to_bits(),
                "leaf {i} entry {j}: 1-worker {xa} vs 4-worker {xb}"
            );
        }
    }
}

#[test]
fn per_leaf_lr_mult_matches_legacy() {
    let leaves = vec![
        Tensor::from_vec(&[16], vec![0.5; 16]),
        Tensor::from_vec(&[16], vec![0.5; 16]),
    ];
    let grads = vec![
        Tensor::from_vec(&[16], vec![0.1; 16]),
        Tensor::from_vec(&[16], vec![0.1; 16]),
    ];
    let mut lp = leaves.clone();
    let mut lopt = AdamW::new(&lp);
    lopt.lr_mult = vec![1.0, 4.0];
    let mut g = grads.clone();
    clip_global_norm(&mut g, 1e9);
    lopt.step(&mut lp, &g, 0.01);

    let mut arena = ParamArena::pack(&leaves);
    let mut fopt = FusedAdamW::new(&arena);
    fopt.lr_mult = vec![1.0, 4.0];
    let plan = MaskPlan::full(&arena);
    let garena = ParamArena::pack(&grads);
    fopt.step(&mut arena, garena.data(), &plan, 0.01, 1e9, 1);
    assert_close(&lp, &arena.unpack(), 1e-7, "lr_mult");
}

#[test]
fn fused_sgd_matches_legacy_sgd() {
    let mut rng = Rng::new(21);
    let leaves = random_leaves(&mut rng, 3, 30);
    let mut lp = leaves.clone();
    let mut lopt = Sgd::new(&lp, 0.9);
    let mut arena = ParamArena::pack(&leaves);
    let mut fopt = FusedSgd::new(&arena, 0.9);
    let mut grng = Rng::new(808);
    for _ in 0..5 {
        let g = random_grads(&mut grng, &leaves, 0.1);
        lopt.step(&mut lp, &g, 0.05);
        let ga = ParamArena::pack(&g);
        fopt.step(&mut arena, ga.data(), 0.05, 2);
        // SGD has no cross-leaf reduction: results are exactly equal
    }
    assert_close(&lp, &arena.unpack(), 0.0, "sgd");
}
