// Fixture: unsafe without a SAFETY: justification must be flagged.

fn bad() -> i32 {
    unsafe { std::mem::transmute::<u32, i32>(1) }
}

struct Wrapper(*const u8);
unsafe impl Send for Wrapper {}
