// Fixture: properly annotated unsafe must pass.

fn good() -> i32 {
    // SAFETY: u32 and i32 have identical size and alignment; any bit
    // pattern is valid for both.
    unsafe { std::mem::transmute::<u32, i32>(1) }
}

struct Wrapper(*const u8);

// SAFETY: the pointer is only ever read on the owning thread; Send/Sync
// here only move the (opaque) handle between threads.
unsafe impl Send for Wrapper {}
unsafe impl Sync for Wrapper {}
