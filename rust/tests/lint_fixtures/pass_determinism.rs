// Fixture: annotated telemetry sites and ordered maps pass in a
// determinism-scoped file.

use std::collections::BTreeMap;
// telemetry only, never recorded — lint: allow(determinism)
use std::time::Instant;

fn fine() -> usize {
    let m: BTreeMap<u32, u32> = BTreeMap::new();
    let _t = Instant::now(); // lint: allow(determinism) telemetry
    m.len()
}
