// Fixture: going through the typed registry passes; a local `var` function
// is not an env read.

fn fine() -> usize {
    var(3)
}

fn var(x: usize) -> usize {
    x + 1
}
