// Fixture: panic-adjacent code the no-panic rule must NOT flag.

fn fine(o: Option<u32>) -> u32 {
    let a = o.unwrap_or(1);
    let b = o.unwrap_or_else(|| 2);
    let c = o.unwrap_or_default();
    let d: Result<u32, u32> = Err(3);
    let e = d.expect_err("always err");
    let s = "calls .unwrap() and panic! inside a string";
    let t = s.len() as u32; // comment saying .expect( is also fine
    a + b + c + e + t
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap() {
        Some(1).unwrap();
    }
}
