// Fixture: nondeterminism sources in a determinism-scoped file (the test
// presents this file under a scoped path, e.g. rust/src/optim.rs).

use std::collections::HashMap;
use std::time::{Instant, SystemTime};

fn bad() -> usize {
    let m: HashMap<u32, u32> = HashMap::new();
    let _t = Instant::now();
    let _s = SystemTime::now();
    m.len()
}
