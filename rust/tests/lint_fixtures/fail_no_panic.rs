// Fixture: every line the no-panic rule must flag (and a few it must not).
// Not compiled — consumed by rust/tests/repolint_selfcheck.rs as data.

fn bad(o: Option<u32>) -> u32 {
    let a = o.unwrap(); // flagged
    let b = o.expect("present"); // flagged
    if a > 3 {
        panic!("boom"); // flagged
    }
    if b > 4 {
        todo!() // flagged
    }
    if a + b > 9 {
        unimplemented!() // flagged
    }
    a + b
}
