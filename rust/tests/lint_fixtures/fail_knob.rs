// Fixture: raw env reads outside the knob registry must be flagged (the
// test presents this file under rust/src/).

fn bad() -> Option<String> {
    std::env::var("SSM_PEFT_SOMETHING").ok()
}
