//! Text-to-SQL example (Spider analogue): fine-tune Mamba with SDT+LoRA,
//! then serve predictions with greedy AND beam search, scoring *execution
//! accuracy* against the mini in-memory database — the real Spider metric,
//! not string match.
//!
//! Run: `cargo run --release --example text2sql`

use ssm_peft::error::Result;
use ssm_peft::config::ExperimentConfig;
use ssm_peft::coordinator::Pipeline;
use ssm_peft::suite::VariantId;
use ssm_peft::data::minidb::exec_match;
use ssm_peft::data::tasks::{self, spider_table};
use ssm_peft::eval::Generator;
use ssm_peft::manifest::Manifest;
use ssm_peft::peft::merge_lora;
use ssm_peft::runtime::Engine;
use ssm_peft::train::{TrainConfig, Trainer};

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let pipeline = Pipeline::new(&engine, &manifest);

    let mut cfg = ExperimentConfig::default();
    cfg.variant = "mamba1_xs_sdtlora".into();
    cfg.dataset = "spider".into();
    cfg.n_train = 384;
    cfg.epochs = 4;
    cfg.max_batches_per_epoch = 20;
    cfg.pretrain_steps = 150;
    cfg.lr_grid = vec![3e-3];
    cfg.gen_max_new = 48;

    println!("fine-tuning {} on the Spider analogue ...", cfg.variant);
    let out = pipeline.finetune(&cfg)?;
    println!("greedy execution accuracy: {:.3} (budget {:.2}%)",
             out.scores["exec"], out.budget_pct);

    // ---- beam-search demo on a few test questions ---------------------------
    // re-run the training quickly to get the parameters (finetune() consumed
    // its trainer); in a service you would checkpoint instead.
    let vid = VariantId::parse(&cfg.variant)?;
    let base = pipeline.pretrained(&vid.arch, cfg.pretrain_steps, cfg.seed)?;
    let tcfg = TrainConfig { lr: out.chosen_lr, schedule_total: 80, ..Default::default() };
    let mut tr = Trainer::new(&engine, &manifest, &cfg.variant, &tcfg)?;
    tr.load_base(&base);
    let ds = tasks::by_name("spider", cfg.seed, cfg.n_train)?;
    let mut rng = ssm_peft::tensor::Rng::new(7);
    for _ in 0..2 {
        let it = ssm_peft::data::BatchIter::new(
            &ds.train, &mut rng, tr.variant.batch_b, tr.variant.batch_l);
        for (batch, _) in it.take(20) {
            tr.step(&batch)?;
        }
    }
    let mut merged = tr.params_map();
    merge_lora(&mut merged, &tr.variant.peft);
    let gen = Generator::new(&engine, &manifest, &vid.decode_variant(), &merged)?;
    let table = spider_table(cfg.seed);

    println!("\nbeam-search (width 4) vs greedy on 4 test questions:");
    let mut beam_hits = 0;
    for ex in ds.test.iter().take(4) {
        let gold = String::from_utf8_lossy(&ex.target).to_string();
        let beam = gen.beam(&ex.prompt, 4, 40, b'\n', None)?;
        let beam_s = String::from_utf8_lossy(&beam).to_string();
        let hit = exec_match(&table, &beam_s, &gold);
        beam_hits += hit as usize;
        println!("  Q: {}", String::from_utf8_lossy(&ex.prompt));
        println!("  gold: {gold}");
        println!("  beam: {beam_s}   [{}]", if hit { "exec ✓" } else { "exec ✗" });
    }
    println!("beam exec hits: {beam_hits}/4");
    Ok(())
}
