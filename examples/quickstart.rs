//! Quickstart: the smallest end-to-end use of the public API.
//!
//!   1. load the AOT manifest + PJRT engine
//!   2. pretrain (or reuse) a tiny Mamba base model
//!   3. fine-tune it on the RTE analogue with LoRA on the linear projections
//!      (the paper's best existing-PEFT configuration)
//!   4. fine-tune the same model with SDT+LoRA (the paper's method)
//!   5. print both accuracies and parameter budgets
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use ssm_peft::error::Result;
use ssm_peft::config::ExperimentConfig;
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    println!("PJRT platform: {} | {} artifact variants", engine.platform(),
             manifest.variants.len());
    let pipeline = Pipeline::new(&engine, &manifest);

    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "glue/rte".into();
    cfg.n_train = 256;
    cfg.epochs = 3;
    cfg.max_batches_per_epoch = 16;
    cfg.pretrain_steps = 150;
    cfg.lr_grid = vec![3e-3];

    for variant in ["mamba1_xs_lora_lin", "mamba1_xs_sdtlora"] {
        cfg.variant = variant.into();
        let out = pipeline.finetune(&cfg)?;
        println!(
            "{:<24} acc={:.3}  trainable={:.2}%  lr={}  steps={}",
            variant, out.metric, out.budget_pct, out.chosen_lr, out.steps
        );
    }
    println!("done — see results/ for loss curves and cached checkpoints");
    Ok(())
}
