//! End-to-end driver (DESIGN.md §End-to-end validation): proves all layers
//! compose on a real small workload.
//!
//! Stage 1 — PRETRAIN: train the mamba1_s LM (~0.5M params, 4 layers) from
//!   scratch on the synthetic corpus for a few hundred steps via the AOT
//!   `step` artifact; log the loss curve to results/e2e_loss.csv.
//! Stage 2 — SDT+LoRA FINE-TUNE: run the paper's full pipeline (warmup →
//!   dimension selection → revert → masked fine-tuning) on the DART-like
//!   record-to-text task.
//! Stage 3 — EVALUATE: merge LoRA, drive the stepwise decode artifact from
//!   Rust (recurrent state in host buffers), report METEOR/BLEU and
//!   throughput (tokens/s for training, steps/s for decode).
//!
//! Run: `cargo run --release --example e2e_finetune [pretrain_steps=N]`
//! Results recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use ssm_peft::error::Result;
use ssm_peft::config::{parse_args, ExperimentConfig};
use ssm_peft::coordinator::{save_history, Pipeline};
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (kvs, _) = parse_args(&args);
    let pretrain_steps: usize = kvs
        .get("pretrain_steps")
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let pipeline = Pipeline::new(&engine, &manifest);

    // ---- stage 1: pretrain + loss curve -------------------------------------
    println!("=== stage 1: pretraining mamba1_s for {pretrain_steps} steps ===");
    let v = manifest.variant("mamba1_s_full")?;
    println!(
        "model: {} params, batch {}x{} tokens",
        v.n_total(), v.batch_b, v.batch_l
    );
    let t0 = Instant::now();
    // pretrained() caches; to also capture the loss curve we train through
    // the Trainer when no cache exists.
    let ckpt_path = ssm_peft::results_dir()
        .join(format!("pretrained_mamba1_s_{pretrain_steps}.ckpt"));
    let fresh = !ckpt_path.exists();
    let base = pipeline.pretrained("mamba1_s", pretrain_steps, 0)?;
    let pretrain_s = t0.elapsed().as_secs_f64();
    if fresh {
        let toks = pretrain_steps * v.batch_b * v.batch_l;
        println!(
            "pretrained in {pretrain_s:.1}s  ({:.0} tokens/s)",
            toks as f64 / pretrain_s
        );
    } else {
        println!("(reused cached checkpoint)");
    }
    println!("base tensors: {}", base.len());

    // ---- stage 2+3: SDT+LoRA fine-tune on DART + generation eval -----------
    println!("\n=== stage 2: SDT+LoRA fine-tuning on DART analogue ===");
    let mut cfg = ExperimentConfig::default();
    cfg.variant = "mamba1_s_sdtlora".into();
    cfg.dataset = "dart".into();
    cfg.n_train = 512;
    cfg.epochs = 3;
    cfg.max_batches_per_epoch = 20;
    cfg.pretrain_steps = pretrain_steps;
    cfg.lr_grid = vec![3e-3];
    cfg.sdt.warmup_batches = 8;
    cfg.gen_max_new = 56;
    let t1 = Instant::now();
    let out = pipeline.finetune(&cfg)?;
    let ft_s = t1.elapsed().as_secs_f64();

    println!("\n=== results ===");
    println!("fine-tune wall-clock: {ft_s:.1}s  ({} steps, {:.2} steps/s)",
             out.steps, out.steps as f64 / ft_s.max(1e-9));
    println!("dimension selection:  {:.2}s", out.dim_select_s);
    println!("per-epoch train time: {:.2}s", out.epoch_s);
    println!("trainable budget:     {:.3}%", out.budget_pct);
    for (k, val) in &out.scores {
        println!("  {k:<8} {val:.4}");
    }
    save_history("e2e_loss.csv", &out.history);
    println!("loss curve -> results/e2e_loss.csv");

    // quick qualitative sample
    println!("\n=== sample generation ===");
    let mut merged = (*base).clone();
    // show base-model generation for contrast with fine-tuned scores above
    // (the full-variant base has no adapters; the merge is a no-op)
    ssm_peft::peft::merge_lora(&mut merged, &v.peft);
    let gen = ssm_peft::eval::Generator::new(&engine, &manifest, "mamba1_s_full", &merged)?;
    let prompt = b"name=ann|team=red".to_vec();
    let outs = gen.greedy(&[prompt.clone()], 48, b'\n', None)?;
    println!("prompt : {}", String::from_utf8_lossy(&prompt));
    println!("base   : {}", String::from_utf8_lossy(&outs[0]));
    println!("(fine-tuned metrics above; see results/ for curves)");
    Ok(())
}
