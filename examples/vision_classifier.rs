//! Vision-as-sequence example (CIFAR-10 analogue): the paper's Sec. B
//! protocol of flattening pixels into token sequences, on BOTH model
//! families — deep S4 (Table 19) and Mamba — comparing full fine-tuning,
//! LoRA, and SDT+LoRA at matched budgets.
//!
//! Run: `cargo run --release --example vision_classifier`

use ssm_peft::error::Result;
use ssm_peft::bench::TablePrinter;
use ssm_peft::config::ExperimentConfig;
use ssm_peft::coordinator::Pipeline;
use ssm_peft::manifest::Manifest;
use ssm_peft::runtime::Engine;

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load(ssm_peft::artifacts_dir())?;
    let pipeline = Pipeline::new(&engine, &manifest);

    let mut table = TablePrinter::new(&["model", "method", "params %", "accuracy"]);
    let runs = [
        ("s4lm_full", "full FT"),
        ("s4lm_s4_lora_proj", "LoRA(W)"),
        ("s4lm_sdtlora", "SDT+LoRA"),
        ("mamba1_xs_lora_lin", "LoRA(LinProj)"),
        ("mamba1_xs_sdtlora", "SDT+LoRA"),
    ];
    for (variant, label) in runs {
        let mut cfg = ExperimentConfig::default();
        cfg.variant = variant.into();
        cfg.dataset = "cifar10".into();
        cfg.n_train = 320;
        cfg.epochs = 3;
        cfg.max_batches_per_epoch = 16;
        cfg.pretrain_steps = 150;
        cfg.lr_grid = vec![3e-3];
        let out = pipeline.finetune(&cfg)?;
        table.row(vec![
            variant.split('_').next().unwrap().to_string(),
            label.to_string(),
            format!("{:.2}", out.budget_pct),
            format!("{:.3}", out.metric),
        ]);
    }
    table.print();
    table.save_csv("example_vision.csv");
    Ok(())
}
