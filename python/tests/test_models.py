"""L2 model zoo: shapes, losses, gradients, and PEFT wiring per variant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model as M, peft as P
from compile.ssm.common import ArchSpec

TINY = {
    "mamba1": ArchSpec(kind="mamba1", d_model=8, n_layer=2, d_inner=16,
                       d_state=4, d_conv=4, dt_rank=2, vocab=32),
    "mamba2": ArchSpec(kind="mamba2", d_model=8, n_layer=2, d_inner=16,
                       d_state=4, d_conv=4, dt_rank=2, vocab=32),
    "s4lm": ArchSpec(kind="s4lm", d_model=8, n_layer=2, d_state=4, vocab=32),
    "s4reg": ArchSpec(kind="s4reg", d_model=8, n_layer=2, d_state=4),
    "hybrid": ArchSpec(kind="hybrid", d_model=8, n_layer=2, d_inner=16,
                       d_state=4, d_conv=4, dt_rank=2, n_head=2, vocab=32),
}


def batch_for(spec, B=2, L=6):
    if spec.is_reg:
        x = jnp.ones((B, L, spec.d_model))
        t = jnp.zeros((B, L, spec.d_model))
    else:
        x = jnp.zeros((B, L), jnp.int32)
        t = jnp.ones((B, L), jnp.int32)
    return x, t, jnp.ones((B, L))


@pytest.mark.parametrize("kind", list(TINY))
def test_forward_shapes(kind):
    spec = TINY[kind]
    params, _ = M.init_model(0, spec, {"method": "full"})
    f = M.forward_fn(spec, {"method": "full"})
    x, _, _ = batch_for(spec)
    y = f(params, x)
    if spec.is_reg:
        assert y.shape == (2, 6, spec.d_model)
    else:
        assert y.shape == (2, 6, spec.vocab)
    assert np.all(np.isfinite(np.asarray(y)))


@pytest.mark.parametrize("kind", list(TINY))
def test_step_loss_and_grads_finite(kind):
    spec = TINY[kind]
    peft = {"method": "full"}
    params, tr = M.init_model(0, spec, peft)
    step, _ = M.step_fn(spec, peft, tr)
    train = {k: params[k] for k in tr}
    frozen = {k: v for k, v in params.items() if k not in train}
    x, t, m = batch_for(spec)
    loss, grads = step(train, frozen, x, t, m)
    assert np.isfinite(float(loss))
    for k, g in grads.items():
        assert np.all(np.isfinite(np.asarray(g))), k
        assert g.shape == params[k].shape


@pytest.mark.parametrize("method,expected_sub", [
    ("lora", ".lora_a"),
    ("dora", ".dora_m"),
    ("bitfit", "conv.b"),
    ("prompt", "prompt"),
    ("prefix", "prefix"),
    ("initstate", ".h0"),
    ("addscan", "A_log_add"),
    ("sdt", "A_log"),
])
def test_peft_trainable_sets(method, expected_sub):
    spec = TINY["mamba1"]
    peft = {"method": method, "targets": ["linproj"], "rank": 2, "alpha": 2,
            "n_tokens": 3}
    params, tr = M.init_model(0, spec, peft)
    assert any(expected_sub in n for n in tr), tr
    # trainable is a strict, nonempty subset for all PEFT methods
    assert 0 < len(tr) < len(params)
    # every trainable name exists in params
    assert all(n in params for n in tr)


def test_lora_zero_init_is_identity():
    """With lora_b = 0, the PEFT model must equal the base model."""
    spec = TINY["mamba1"]
    base_params, _ = M.init_model(0, spec, {"method": "full"})
    peft = {"method": "lora", "targets": ["both"], "rank": 2, "alpha": 2}
    lora_params, _ = M.init_model(0, spec, peft)
    x, _, _ = batch_for(spec)
    y_base = M.forward_fn(spec, {"method": "full"})(base_params, x)
    y_lora = M.forward_fn(spec, peft)(lora_params, x)
    np.testing.assert_allclose(y_base, y_lora, rtol=1e-5, atol=1e-6)


def test_lora_grads_nonzero_after_first_step():
    """d loss/d lora_a is nonzero even with lora_b=0 requires a step first;
    here we check d loss/d lora_b is nonzero immediately (a != 0)."""
    spec = TINY["mamba1"]
    peft = {"method": "lora", "targets": ["linproj"], "rank": 2, "alpha": 2}
    params, tr = M.init_model(0, spec, peft)
    step, _ = M.step_fn(spec, peft, tr)
    train = {k: params[k] for k in tr}
    frozen = {k: v for k, v in params.items() if k not in train}
    x, t, m = batch_for(spec)
    _, grads = step(train, frozen, x, t, m)
    gb = [np.abs(np.asarray(g)).max() for k, g in grads.items() if k.endswith("lora_b")]
    assert max(gb) > 0


def test_merge_lora_matches_adapter_forward():
    spec = TINY["mamba1"]
    peft = {"method": "lora", "targets": ["linproj"], "rank": 2, "alpha": 2}
    params, tr = M.init_model(0, spec, peft)
    # make adapters non-trivial
    params = dict(params)
    for k in list(params):
        if k.endswith("lora_b"):
            params[k] = params[k] + 0.3
    x, _, _ = batch_for(spec)
    y_adapter = M.forward_fn(spec, peft)(params, x)
    merged = P.merge_lora(params, peft)
    y_merged = M.forward_fn(spec, {"method": "full"})(merged, x)
    np.testing.assert_allclose(y_adapter, y_merged, rtol=1e-4, atol=1e-5)


def test_prompt_tuning_preserves_output_length():
    spec = TINY["mamba1"]
    peft = {"method": "prompt", "n_tokens": 5}
    params, _ = M.init_model(0, spec, peft)
    x, _, _ = batch_for(spec, B=2, L=6)
    y = M.forward_fn(spec, peft)(params, x)
    assert y.shape == (2, 6, spec.vocab)


def test_prefix_changes_output_but_not_shape():
    spec = TINY["mamba1"]
    peft = {"method": "prefix", "n_tokens": 3}
    params, tr = M.init_model(0, spec, peft)
    x, _, _ = batch_for(spec)
    y0 = M.forward_fn(spec, peft)(params, x)
    params2 = dict(params)
    for n in tr:
        params2[n] = params2[n] + 1.0
    y1 = M.forward_fn(spec, peft)(params2, x)
    assert y0.shape == y1.shape
    assert np.abs(np.asarray(y0 - y1)).max() > 1e-4


def test_addscan_extra_states_change_model():
    spec = TINY["mamba1"]
    peft = {"method": "addscan"}
    params, tr = M.init_model(0, spec, peft)
    x, _, _ = batch_for(spec)
    y0 = M.forward_fn(spec, peft)(params, x)
    params2 = dict(params)
    for n in tr:
        if "xproj_add" in n:
            params2[n] = params2[n] + 0.5
    y1 = M.forward_fn(spec, peft)(params2, x)
    assert np.abs(np.asarray(y0 - y1)).max() > 1e-5


def test_mamba_decode_matches_forward():
    """Stepwise decode must reproduce the full forward logits position by
    position (the recurrent/parallel consistency that makes Mamba Mamba)."""
    spec = TINY["mamba1"]
    peft = {"method": "full"}
    params, _ = M.init_model(0, spec, peft)
    B, L = 2, 5
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 31, (B, L)), jnp.int32)
    logits_full = M.forward_fn(spec, peft)(params, tokens)
    dec = M.decode_fn(spec, peft)
    conv = jnp.zeros((spec.n_layer, B, spec.d_conv - 1, spec.d_inner))
    ssm = jnp.zeros((spec.n_layer, B, spec.d_inner, spec.d_state))
    for t in range(L):
        logits_t, conv, ssm = dec(params, tokens[:, t], conv, ssm)
        np.testing.assert_allclose(
            logits_t, logits_full[:, t], rtol=2e-3, atol=2e-3,
            err_msg=f"position {t}")


def test_prefill_chunk_matches_stepwise_decode():
    """Chunked prefill must be exactly one-scan-equals-many-steps: the final
    (conv, ssm) state and last-position logits of a (B, C) chunk equal C
    iterations of decode_step over the same tokens, including across a
    chunk boundary with a carried non-zero state."""
    spec = TINY["mamba1"]
    peft = {"method": "full"}
    params, _ = M.init_model(0, spec, peft)
    B, L, C = 2, 11, 4
    tokens = jnp.asarray(np.random.default_rng(1).integers(0, 31, (B, L)),
                         jnp.int32)
    dec = M.decode_fn(spec, peft)
    pf = M.prefill_fn(spec, peft)
    conv_s = jnp.zeros((spec.n_layer, B, spec.d_conv - 1, spec.d_inner))
    ssm_s = jnp.zeros((spec.n_layer, B, spec.d_inner, spec.d_state))
    conv_c, ssm_c = conv_s, ssm_s
    pos = 0
    # two full chunks via prefill, then the remainder; compare against the
    # stepwise path after every segment
    for seg in (C, C, L - 2 * C):
        logits_c, conv_c, ssm_c = pf(params, tokens[:, pos:pos + seg],
                                     conv_c, ssm_c)
        for t in range(pos, pos + seg):
            logits_s, conv_s, ssm_s = dec(params, tokens[:, t], conv_s, ssm_s)
        pos += seg
        np.testing.assert_allclose(logits_c, logits_s, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(conv_c, conv_s, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(ssm_c, ssm_s, rtol=1e-5, atol=1e-5)


def test_prefill_chunk_shorter_than_conv_window():
    """A chunk narrower than the conv kernel (C < K-1) must still carry the
    window correctly — the serve planner can emit such tails."""
    spec = TINY["mamba1"]
    peft = {"method": "full"}
    params, _ = M.init_model(0, spec, peft)
    B, L = 2, 6
    tokens = jnp.asarray(np.random.default_rng(2).integers(0, 31, (B, L)),
                         jnp.int32)
    dec = M.decode_fn(spec, peft)
    pf = M.prefill_fn(spec, peft)
    conv = jnp.zeros((spec.n_layer, B, spec.d_conv - 1, spec.d_inner))
    ssm = jnp.zeros((spec.n_layer, B, spec.d_inner, spec.d_state))
    for t in range(L - 2):
        _, conv, ssm = dec(params, tokens[:, t], conv, ssm)
    logits_c, conv_c, ssm_c = pf(params, tokens[:, L - 2:], conv, ssm)  # C=2
    for t in (L - 2, L - 1):
        logits_s, conv, ssm = dec(params, tokens[:, t], conv, ssm)
    np.testing.assert_allclose(logits_c, logits_s, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(conv_c, conv, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ssm_c, ssm, rtol=1e-5, atol=1e-5)


def test_variant_registry_complete():
    vs = configs.variants()
    names = [v["name"] for v in vs]
    assert len(names) == len(set(names)), "duplicate variant names"
    # every referenced arch/peft exists
    for v in vs:
        assert v["spec"].kind in ("mamba1", "mamba2", "s4lm", "s4reg", "hybrid")
        assert "method" in v["peft"]
    # the decode anchors exist
    assert any(v["decode"] for v in vs if v["arch"] == "mamba1_xs")
