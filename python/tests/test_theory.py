"""Numerical verification of the paper's theoretical results.

- Lemma 1  (Sec. 4 / C.4): fine-tuning the input projection W_in,1 can absorb
  any change to (W_B, W_C, W_Δ↑) via the SVD construction of Eq. (15).
- Proposition 1 (Sec. C.3): prefix-tuning an S4 mechanism is equivalent to
  tuning the initial hidden state; the converse holds iff M ≥ H.
- Lemma 2  (Sec. 5.1 / D.1): a frozen single-channel S4 can match a smaller
  target by aligning (Ā, B̄⊙C) on H* dims and zeroing the rest, with the
  permutation-invariance the proof relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import s4_scan_ref, selective_scan_ref


# ---------------------------------------------------------------------------
# Lemma 1
# ---------------------------------------------------------------------------

def s6_two_proj_forward(x, A, WB, WC, Wdn, Wup, Win1, Win2):
    """S6 with two input projections (paper Sec. C.4 notation).

    x (B, N, D); parameters as in Eq. (9)-(10) with β_Δ = 0.
    """
    u1 = x @ Win1.T                       # input for parameter computation
    u2 = x @ Win2.T                       # input fed to the SSM
    delta = jax.nn.softplus(u1 @ (Wdn @ Wup).T)     # (B, N, D)
    Bmat = u1 @ WB.T                                # (B, N, H)
    C = u1 @ WC.T                                   # (B, N, H)
    y, _ = selective_scan_ref(u2, delta, A, Bmat, C,
                              jnp.zeros((x.shape[0], A.shape[0], A.shape[1])))
    return y


def test_lemma1_svd_construction_matches_target():
    D, H, R, B, N = 12, 3, 2, 2, 6  # D > 2H + R
    rng = np.random.default_rng(0)

    def mat(*shape, scale=0.5):
        return jnp.asarray(scale * rng.normal(size=shape), jnp.float32)

    A = -jnp.asarray(rng.uniform(0.2, 1.0, size=(D, H)), jnp.float32)
    Wdn = mat(D, R)          # W_Δ,↓ shared
    Win2 = mat(D, D)         # shared
    # target parameters (starred)
    WB_t, WC_t, Wup_t, Win1_t = mat(H, D), mat(H, D), mat(R, D), mat(D, D)
    # frozen parameters
    WB_f, WC_f, Wup_f = mat(H, D), mat(H, D), mat(R, D)

    # construct Ŵ_in,1 via Eq. (15): W_S6 = [W_B; W_C; W_Δ↑] (2H+R, D)
    WS6_f = jnp.concatenate([WB_f, WC_f, Wup_f], axis=0)
    WS6_t = jnp.concatenate([WB_t, WC_t, Wup_t], axis=0)
    U, S, Vt = jnp.linalg.svd(WS6_f, full_matrices=True)   # (k,k),(k,),(D,D)
    k = 2 * H + R
    target_prod = WS6_t @ Win1_t                            # (k, D)
    top = jnp.diag(1.0 / S) @ U.T @ target_prod             # (k, D)
    Q = jnp.zeros((D - k, D), jnp.float32)                  # arbitrary
    Win1_hat = Vt.T @ jnp.concatenate([top, Q], axis=0)     # (D, D)

    # the construction must satisfy W_S6^f Ŵ_in,1 = W_S6* W_in,1*
    np.testing.assert_allclose(WS6_f @ Win1_hat, target_prod, rtol=2e-4, atol=2e-4)

    x = mat(B, N, D, scale=1.0)
    y_target = s6_two_proj_forward(x, A, WB_t, WC_t, Wdn, Wup_t, Win1_t, Win2)
    y_frozen_hat = s6_two_proj_forward(x, A, WB_f, WC_f, Wdn, Wup_f, Win1_hat, Win2)
    np.testing.assert_allclose(y_frozen_hat, y_target, rtol=2e-3, atol=2e-3)


def test_lemma1_requires_capacity():
    """With D < 2H+R the construction is impossible in general: W_S6^f has
    rank ≤ D < rows, so some targets are unreachable."""
    D, H, R = 4, 3, 2  # 2H+R = 8 > 4
    rng = np.random.default_rng(1)
    WS6_f = jnp.asarray(rng.normal(size=(2 * H + R, D)), jnp.float32)
    # a random full-rank target product is (generically) outside the column
    # space of W_S6^f ∘ (D×D matrices), which has rank ≤ D
    target = jnp.asarray(rng.normal(size=(2 * H + R, D)), jnp.float32)
    # least-squares best approximation still has large residual
    sol, *_ = jnp.linalg.lstsq(WS6_f, target)
    residual = jnp.linalg.norm(WS6_f @ sol - target)
    assert float(residual) > 1e-2


# ---------------------------------------------------------------------------
# Proposition 1
# ---------------------------------------------------------------------------

def s4_single_channel(x, Abar, Bbar, C, h0):
    """Single-channel discrete S4: x (N,), diag(Abar),Bbar,C (H,)."""
    y, hl = s4_scan_ref(
        x[None, :, None],
        Abar[None, :], Bbar[None, :], C[None, :],
        h0[None, None, :],
    )
    return y[0, :, 0], hl[0, 0]


def test_prop1_prefix_equals_initial_state():
    H, M, N = 4, 6, 10
    rng = np.random.default_rng(2)
    Abar = jnp.asarray(rng.uniform(0.3, 0.9, size=H), jnp.float32)
    Bbar = jnp.asarray(rng.normal(size=H), jnp.float32)
    C = jnp.asarray(rng.normal(size=H), jnp.float32)
    p = jnp.asarray(rng.normal(size=M), jnp.float32)
    x = jnp.asarray(rng.normal(size=N), jnp.float32)
    zeros = jnp.zeros(H, jnp.float32)

    # run prefix + input with zero initial state
    y_pref, _ = s4_single_channel(jnp.concatenate([p, x]), Abar, Bbar, C, zeros)
    y_pref = y_pref[M:]
    # equivalent initial state: h0* = sum_m Abar^{M-m} Bbar p_m
    h0 = jnp.zeros(H)
    for m in range(M):
        h0 = Abar * h0 + Bbar * p[m]
    y_ist, _ = s4_single_channel(x, Abar, Bbar, C, h0)
    np.testing.assert_allclose(y_pref, y_ist, rtol=1e-5, atol=1e-5)


def test_prop1_converse_iff_m_geq_h():
    """The reachable set of initial states is span(Abar^{M-1}B,...,B):
    full-rank iff M >= H (distinct Abar, nonzero Bbar)."""
    H = 4
    rng = np.random.default_rng(3)
    Abar = jnp.asarray(np.linspace(0.3, 0.9, H), jnp.float32)  # distinct
    Bbar = jnp.asarray(rng.normal(size=H) + 2.0, jnp.float32)  # nonzero

    def reach_rank(M):
        cols = []
        for m in range(M):
            cols.append((Abar ** (M - 1 - m)) * Bbar)
        mat = np.stack(cols, axis=1)
        return np.linalg.matrix_rank(mat, tol=1e-5)

    assert reach_rank(H - 1) < H      # M < H: not all h0 reachable
    assert reach_rank(H) == H         # M = H: all h0 reachable
    assert reach_rank(H + 3) == H


def test_prop1_rank_deficient_when_assumptions_fail():
    """Repeated Abar eigenvalues (Vandermonde zero) break the converse even
    with M = H — exactly the paper's non-degeneracy assumption."""
    H = 4
    Abar = jnp.asarray([0.5, 0.5, 0.7, 0.9], jnp.float32)  # repeated
    Bbar = jnp.ones(H, jnp.float32)
    cols = [np.asarray((Abar ** (H - 1 - m)) * Bbar) for m in range(H)]
    assert np.linalg.matrix_rank(np.stack(cols, 1), tol=1e-5) < H


# ---------------------------------------------------------------------------
# Lemma 2
# ---------------------------------------------------------------------------

def test_lemma2_alignment_achieves_equivalence():
    """Frozen H=6 model matches a target H*=2 model by (i) permuting, (ii)
    aligning Ā and B̄⊙C on the first H* dims, (iii) zeroing C elsewhere."""
    H, Hs, N = 6, 2, 12
    rng = np.random.default_rng(4)
    # target
    Abar_t = jnp.asarray(rng.uniform(0.3, 0.9, size=Hs), jnp.float32)
    Bbar_t = jnp.asarray(rng.normal(size=Hs), jnp.float32)
    C_t = jnp.asarray(rng.normal(size=Hs), jnp.float32)
    # frozen (random)
    Abar_f = jnp.asarray(rng.uniform(0.3, 0.9, size=H), jnp.float32)
    Bbar_f = jnp.asarray(rng.normal(size=H) + 1.5, jnp.float32)
    C_f = jnp.asarray(rng.normal(size=H), jnp.float32)

    # updated model: align first Hs dims, zero the rest via C (B̄⊙C equivalence)
    Abar_u = Abar_f.at[:Hs].set(Abar_t)
    C_u = C_f.at[:Hs].set(Bbar_t * C_t / Bbar_f[:Hs])   # tune C only (B frozen)
    C_u = C_u.at[Hs:].set(0.0)

    x = jnp.asarray(rng.normal(size=N), jnp.float32)
    y_t, _ = s4_single_channel(x, Abar_t, Bbar_t, C_t, jnp.zeros(Hs))
    y_u, _ = s4_single_channel(x, Abar_u, Bbar_f, C_u, jnp.zeros(H))
    np.testing.assert_allclose(y_u, y_t, rtol=1e-4, atol=1e-5)


def test_lemma2_permutation_invariance():
    """Permuting hidden dims leaves the S4 function unchanged (the search
    space of Lemma 2)."""
    H, N = 5, 9
    rng = np.random.default_rng(5)
    Abar = jnp.asarray(rng.uniform(0.2, 0.9, size=H), jnp.float32)
    Bbar = jnp.asarray(rng.normal(size=H), jnp.float32)
    C = jnp.asarray(rng.normal(size=H), jnp.float32)
    x = jnp.asarray(rng.normal(size=N), jnp.float32)
    perm = np.asarray([3, 1, 4, 0, 2])
    y1, _ = s4_single_channel(x, Abar, Bbar, C, jnp.zeros(H))
    y2, _ = s4_single_channel(x, Abar[perm], Bbar[perm], C[perm], jnp.zeros(H))
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


def test_lemma2_b_c_interchangeable():
    """B̄ and C only enter through B̄⊙C: scaling one and inverse-scaling the
    other is a no-op (third term of Eq. (5))."""
    H, N = 4, 8
    rng = np.random.default_rng(6)
    Abar = jnp.asarray(rng.uniform(0.3, 0.9, size=H), jnp.float32)
    Bbar = jnp.asarray(rng.normal(size=H) + 2.0, jnp.float32)
    C = jnp.asarray(rng.normal(size=H), jnp.float32)
    s = jnp.asarray(rng.uniform(0.5, 2.0, size=H), jnp.float32)
    x = jnp.asarray(rng.normal(size=N), jnp.float32)
    y1, _ = s4_single_channel(x, Abar, Bbar, C, jnp.zeros(H))
    y2, _ = s4_single_channel(x, Abar, Bbar * s, C / s, jnp.zeros(H))
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
