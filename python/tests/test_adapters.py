"""Unmerged multi-adapter decode: per-row deltas == per-row merged weights.

`s6.decode_step_adapters` must be semantically identical to running
`s6.decode_step` row by row with that row's merged parameters — the Rust
serving path demotes the merged-copy registry on the strength of this
equivalence (plus the Rust-side byte-equivalence harness).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import peft as P
from compile.ssm import s6
from compile.ssm.common import ArchSpec

SPEC = ArchSpec(kind="mamba1", d_model=8, n_layer=2, d_inner=16,
                d_state=4, d_conv=4, dt_rank=2, vocab=32)
SPEC2 = ArchSpec(kind="mamba2", d_model=8, n_layer=2, d_inner=16,
                 d_state=4, d_conv=4, dt_rank=2, vocab=32)
FULL = {"method": "full"}
RANK = 3
K = 8
B = 4


def base_model(spec):
    params, _ = M.init_model(0, spec, FULL)
    return params


def states(spec, rng, B):
    conv = 0.1 * jax.random.normal(
        rng, (spec.n_layer, B, spec.d_conv - 1, spec.d_inner))
    ssm = 0.1 * jax.random.normal(
        jax.random.fold_in(rng, 1),
        (spec.n_layer, B, spec.d_inner, spec.d_state))
    return conv, ssm


def row_slice(states_nb, r):
    """(n_layer, B, ...) -> (n_layer, 1, ...) for row r."""
    return states_nb[:, r:r + 1]


def random_adapters(spec, rng, B, rank=RANK, k=K, lora=True, sdt=True):
    """Random per-row operands + the equivalent per-row merged param dicts."""
    ops = M.zero_adapter_operands(spec, B, rank, k)
    ops = {n: np.array(v) for n, v in ops.items()}
    base = base_model(spec)
    merged = [dict(base) for _ in range(B)]
    rs = np.random.RandomState(int(jax.random.randint(rng, (), 0, 1 << 30)))
    ops["scale"] = np.full((B,), 1.0, np.float32)
    for i in range(spec.n_layer):
        pre = f"layers.{i}."
        if lora:
            for t in s6.LORA_SLOT_TARGETS:
                name = pre + t
                din, dout = M._adapter_target_shape(spec, t)
                for r in range(B):
                    a = 0.05 * rs.randn(din, rank).astype(np.float32)
                    b = 0.05 * rs.randn(rank, dout).astype(np.float32)
                    ops[name + ".lora_a"][r] = a
                    ops[name + ".lora_b"][r] = b
                    merged[r][name] = merged[r][name] + a @ b
        if sdt:
            for p in s6.SDT_SLOT_PARAMS:
                name = pre + p
                size = int(np.prod(M._adapter_target_shape(spec, p)))
                for r in range(B):
                    nz = rs.randint(1, k + 1)
                    idx = rs.choice(size, size=nz, replace=False)
                    val = 0.1 * rs.randn(nz).astype(np.float32)
                    ops[name + ".sdt_idx"][r, :nz] = idx
                    ops[name + ".sdt_val"][r, :nz] = val
                    flat = np.asarray(merged[r][name]).reshape(-1).copy()
                    flat[idx] += val
                    merged[r][name] = jnp.asarray(
                        flat.reshape(merged[r][name].shape))
    ops = {n: jnp.asarray(v) for n, v in ops.items()}
    return base, ops, merged


def run_adapters(spec, base, ops, token, conv, ssm):
    eff = P.make_eff(base, FULL)
    return s6.decode_step_adapters(base, eff, spec, token, conv, ssm, ops)


def run_merged_row(spec, merged_r, token_r, conv_r, ssm_r):
    eff = P.make_eff(merged_r, FULL)
    return s6.decode_step(merged_r, eff, spec, token_r, conv_r, ssm_r)


@pytest.mark.parametrize("spec", [SPEC, SPEC2], ids=["mamba1", "mamba2"])
def test_zero_adapters_match_decode_step(spec):
    base = base_model(spec)
    ops = M.zero_adapter_operands(spec, B, RANK, K)
    rng = jax.random.PRNGKey(7)
    conv, ssm = states(spec, rng, B)
    token = jnp.arange(B, dtype=jnp.int32)
    eff = P.make_eff(base, FULL)
    la, ca, sa = run_adapters(spec, base, ops, token, conv, ssm)
    lb, cb, sb = s6.decode_step(base, eff, spec, token, conv, ssm)
    np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ca, cb, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(sa, sb, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("spec", [SPEC, SPEC2], ids=["mamba1", "mamba2"])
@pytest.mark.parametrize("mode", ["lora", "sdt", "both"])
def test_mixed_rows_match_per_row_merged(spec, mode):
    rng = jax.random.PRNGKey(11)
    base, ops, merged = random_adapters(
        spec, rng, B, lora=mode in ("lora", "both"),
        sdt=mode in ("sdt", "both"))
    conv, ssm = states(spec, jax.random.fold_in(rng, 2), B)
    token = jnp.asarray([3, 1, 4, 1], jnp.int32)
    la, ca, sa = run_adapters(spec, base, ops, token, conv, ssm)
    for r in range(B):
        lr, cr, sr = run_merged_row(
            spec, merged[r], token[r:r + 1], row_slice(conv, r),
            row_slice(ssm, r))
        np.testing.assert_allclose(la[r], lr[0], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(ca[:, r], cr[:, 0], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(sa[:, r], sr[:, 0], rtol=1e-4, atol=1e-5)


def test_multi_step_state_carry():
    """Three chained steps: carried states stay per-row equivalent."""
    spec = SPEC
    rng = jax.random.PRNGKey(23)
    base, ops, merged = random_adapters(spec, rng, B)
    conv = jnp.zeros((spec.n_layer, B, spec.d_conv - 1, spec.d_inner))
    ssm = jnp.zeros((spec.n_layer, B, spec.d_inner, spec.d_state))
    per_row = [(jnp.zeros((spec.n_layer, 1, spec.d_conv - 1, spec.d_inner)),
                jnp.zeros((spec.n_layer, 1, spec.d_inner, spec.d_state)))
               for _ in range(B)]
    token = jnp.asarray([5, 9, 2, 6], jnp.int32)
    for _ in range(3):
        la, conv, ssm = run_adapters(spec, base, ops, token, conv, ssm)
        nxt = []
        for r in range(B):
            cr, sr = per_row[r]
            lr, cr, sr = run_merged_row(spec, merged[r], token[r:r + 1],
                                        cr, sr)
            per_row[r] = (cr, sr)
            np.testing.assert_allclose(la[r], lr[0], rtol=1e-4, atol=1e-5)
            nxt.append(int(jnp.argmax(lr[0])))
        token = jnp.asarray(nxt, jnp.int32)


def test_adapter_operands_table_is_canonical():
    ops = M.adapter_operands(SPEC, B, RANK, K)
    names = [n for n, _, _ in ops]
    assert names[0] == "scale"
    assert len(names) == len(set(names))
    # every lora slot target and sdt param appears per layer
    for i in range(SPEC.n_layer):
        for t in s6.LORA_SLOT_TARGETS:
            assert f"layers.{i}.{t}.lora_a" in names
            assert f"layers.{i}.{t}.lora_b" in names
        for p in s6.SDT_SLOT_PARAMS:
            assert f"layers.{i}.{p}.sdt_idx" in names
            assert f"layers.{i}.{p}.sdt_val" in names
    # shapes carry the requested rank / k
    by = {n: (shape, dt) for n, shape, dt in ops}
    shape, dt = by["layers.0.Win_x.lora_a"]
    assert shape == (B, SPEC.d_model, RANK) and dt == jnp.float32
    shape, dt = by["layers.0.A_log.sdt_idx"]
    assert shape == (B, K) and dt == jnp.int32


def test_aot_exports_decode_adapters(tmp_path):
    from compile import aot
    v = dict(name="tiny_ad", arch="tiny", spec=SPEC, peft_name="full",
             peft=FULL, B=2, L=8, decode=True)
    entry = aot.export_variant(v, str(tmp_path))
    assert "decode_adapters" in entry["files"]
    text = (tmp_path / entry["files"]["decode_adapters"]).read_text()
    assert text.startswith("HloModule")
    meta = entry["adapter_operands"]
    assert meta["rank"] == aot.ADAPTER_RANK and meta["k"] == aot.ADAPTER_K
    ops = M.adapter_operands(SPEC, 2, meta["rank"], meta["k"])
    assert [o["name"] for o in meta["operands"]] == [n for n, _, _ in ops]
    assert all(o["dtype"] in ("f32", "i32") for o in meta["operands"])
