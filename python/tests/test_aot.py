"""AOT pipeline: HLO text export + manifest integrity for one tiny variant."""

import json
import os
import tempfile

from compile import aot, configs


def tiny_variant():
    from compile.ssm.common import ArchSpec
    spec = ArchSpec(kind="mamba1", d_model=8, n_layer=1, d_inner=16,
                    d_state=4, d_conv=4, dt_rank=2, vocab=32)
    return dict(name="tiny_test", arch="tiny", spec=spec, peft_name="lora_lin",
                peft={"method": "lora", "targets": ["linproj"], "rank": 2,
                      "alpha": 2},
                B=2, L=8, decode=True)


def test_export_variant_writes_everything():
    with tempfile.TemporaryDirectory() as d:
        entry = aot.export_variant(tiny_variant(), d)
        # files exist and HLO text parses as HLO (starts with HloModule)
        for key in ("step", "fwd", "decode"):
            path = os.path.join(d, entry["files"][key])
            assert os.path.exists(path)
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), key
        # decode variants also carry one prefill artifact per chunk width
        assert set(entry["files"]["prefill"]) == \
            {str(c) for c in aot.PREFILL_WIDTHS}
        for fname in entry["files"]["prefill"].values():
            path = os.path.join(d, fname)
            assert os.path.exists(path)
            with open(path) as f:
                assert f.read(64).startswith("HloModule"), fname
        # params.bin has the right size
        total = sum(p["numel"] for p in
                    entry["train_params"] + entry["frozen_params"])
        assert os.path.getsize(os.path.join(d, entry["params_bin"])) == 4 * total
        # offsets are disjoint and ordered train-then-frozen
        offs = [p["offset"] for p in entry["train_params"] + entry["frozen_params"]]
        assert offs == sorted(offs)
        # manifest entry is JSON-serializable
        json.dumps(entry)


def test_trainable_partition_is_exact():
    with tempfile.TemporaryDirectory() as d:
        entry = aot.export_variant(tiny_variant(), d)
        train = {p["name"] for p in entry["train_params"]}
        frozen = {p["name"] for p in entry["frozen_params"]}
        assert train.isdisjoint(frozen)
        assert all(".lora_" in n for n in train)
        assert "embed" in frozen


def test_registry_names_are_prefix_consistent():
    for v in configs.variants():
        assert v["name"].startswith(v["arch"]), v["name"]
        assert v["name"].endswith(v["peft_name"]), v["name"]
