"""L1 correctness: Pallas kernels vs pure-jnp oracles (values AND gradients).

Hypothesis sweeps shapes/parameter scales; gradients are checked against
jax.grad of the reference implementation, which exercises the hand-written
backward kernels through jax.custom_vjp.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (s4_conv_ref, s4_scan, s4_scan_ref,
                             selective_scan, selective_scan_ref)

jax.config.update("jax_enable_x64", False)


def rand_inputs(rng, B, L, D, H):
    x = jnp.asarray(rng.normal(size=(B, L, D)), jnp.float32)
    delta = jnp.asarray(rng.uniform(0.05, 0.4, size=(B, L, D)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.1, 2.0, size=(D, H)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, H)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(B, L, H)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, D, H)), jnp.float32)
    return x, delta, A, Bm, C, h0


@pytest.mark.parametrize("B,L,D,H", [(1, 4, 2, 2), (2, 16, 8, 4), (3, 9, 4, 8)])
def test_selective_scan_forward_matches_ref(B, L, D, H):
    rng = np.random.default_rng(B * 100 + L)
    args = rand_inputs(rng, B, L, D, H)
    y1, h1 = selective_scan(*args)
    y2, h2 = selective_scan_ref(*args)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)


def test_selective_scan_zero_h0_equals_no_state():
    rng = np.random.default_rng(0)
    x, delta, A, Bm, C, h0 = rand_inputs(rng, 2, 8, 4, 4)
    z = jnp.zeros_like(h0)
    y1, _ = selective_scan(x, delta, A, Bm, C, z)
    y2, _ = selective_scan_ref(x, delta, A, Bm, C, z)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


def test_selective_scan_grads_match_ref():
    rng = np.random.default_rng(1)
    args = rand_inputs(rng, 2, 10, 8, 4)

    def loss_k(*a):
        y, hl = selective_scan(*a)
        return jnp.sum(jnp.sin(y)) + jnp.sum(hl ** 2)

    def loss_r(*a):
        y, hl = selective_scan_ref(*a)
        return jnp.sum(jnp.sin(y)) + jnp.sum(hl ** 2)

    gk = jax.grad(loss_k, argnums=tuple(range(6)))(*args)
    gr = jax.grad(loss_r, argnums=tuple(range(6)))(*args)
    for name, a, b in zip("x delta A B C h0".split(), gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4, err_msg=name)


def test_selective_scan_chunked_sequential_consistency():
    """Scanning L steps == scanning L/2 then L/2 with carried state."""
    rng = np.random.default_rng(2)
    x, delta, A, Bm, C, h0 = rand_inputs(rng, 2, 12, 4, 4)
    y_full, h_full = selective_scan(x, delta, A, Bm, C, h0)
    y1, h_mid = selective_scan(x[:, :6], delta[:, :6], A, Bm[:, :6], C[:, :6], h0)
    y2, h_end = selective_scan(x[:, 6:], delta[:, 6:], A, Bm[:, 6:], C[:, 6:], h_mid)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h_end, h_full, rtol=1e-4, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 3),
    L=st.integers(1, 24),
    logD=st.integers(0, 5),
    logH=st.integers(0, 4),
    scale=st.floats(0.1, 3.0),
)
def test_selective_scan_hypothesis_sweep(B, L, logD, logH, scale):
    D, H = 2 ** logD, 2 ** logH
    rng = np.random.default_rng(L * 7 + D)
    x, delta, A, Bm, C, h0 = rand_inputs(rng, B, L, D, H)
    x = x * scale
    y1, h1 = selective_scan(x, delta, A, Bm, C, h0)
    y2, h2 = selective_scan_ref(x, delta, A, Bm, C, h0)
    np.testing.assert_allclose(y1, y2, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(h1, h2, rtol=5e-4, atol=5e-4)
    assert not np.any(np.isnan(np.asarray(y1)))


def s4_inputs(rng, B, L, D, H):
    x = jnp.asarray(rng.normal(size=(B, L, D)), jnp.float32)
    Abar = jnp.asarray(rng.uniform(0.2, 0.97, size=(D, H)), jnp.float32)
    Bbar = jnp.asarray(rng.normal(size=(D, H)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(D, H)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, D, H)), jnp.float32)
    return x, Abar, Bbar, C, h0


@pytest.mark.parametrize("B,L,D,H", [(1, 4, 2, 2), (2, 20, 8, 4)])
def test_s4_scan_matches_both_oracles(B, L, D, H):
    rng = np.random.default_rng(B + L)
    args = s4_inputs(rng, B, L, D, H)
    y1, h1 = s4_scan(*args)
    y2, h2 = s4_scan_ref(*args)
    y3 = s4_conv_ref(*args)  # independently-derived convolutional form
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y1, y3, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)


def test_s4_grads_match_ref():
    rng = np.random.default_rng(3)
    args = s4_inputs(rng, 2, 12, 4, 4)

    def loss_k(*a):
        y, hl = s4_scan(*a)
        return jnp.sum(y ** 2) + jnp.sum(hl)

    def loss_r(*a):
        y, hl = s4_scan_ref(*a)
        return jnp.sum(y ** 2) + jnp.sum(hl)

    gk = jax.grad(loss_k, argnums=tuple(range(5)))(*args)
    gr = jax.grad(loss_r, argnums=tuple(range(5)))(*args)
    for name, a, b in zip("x Abar Bbar C h0".split(), gk, gr):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4, err_msg=name)


@settings(max_examples=10, deadline=None)
@given(L=st.integers(1, 32), logD=st.integers(0, 5), logH=st.integers(0, 4))
def test_s4_hypothesis_sweep(L, logD, logH):
    D, H = 2 ** logD, 2 ** logH
    rng = np.random.default_rng(L + D + H)
    args = s4_inputs(rng, 2, L, D, H)
    y1, _ = s4_scan(*args)
    y2, _ = s4_scan_ref(*args)
    np.testing.assert_allclose(y1, y2, rtol=5e-4, atol=5e-4)


def test_s4_stability_long_sequence():
    """|Abar| < 1 keeps the scan bounded over long sequences."""
    rng = np.random.default_rng(4)
    x, Abar, Bbar, C, h0 = s4_inputs(rng, 1, 512, 4, 4)
    y, hl = s4_scan(x, Abar, Bbar, C, h0)
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.abs(np.asarray(hl)).max() < 1e3
