"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the Pallas kernels (selective_scan.py, s4_scan.py)
are tested against, and they are differentiable by plain jax autodiff so the
custom-VJP backward kernels can be checked against `jax.grad` of these.

Shapes (batch B, length L, channels D, states H):
  selective scan (S6, Mamba):
      x     (B, L, D)   per-channel input
      delta (B, L, D)   input-dependent step size (post-softplus)
      A     (D, H)      continuous diagonal state matrix (negative real)
      Bmat  (B, L, H)   input-dependent input-transition (shared over D)
      C     (B, L, H)   input-dependent output map (shared over D)
      h0    (B, D, H)   initial hidden state (zeros unless initial-state
                        tuning / stepwise decode)
    returns y (B, L, D), h_last (B, D, H)

  S4 scan (LTI, per-channel parameters):
      x    (B, L, D)
      Abar (D, H)       discretized diagonal state matrix
      Bbar (D, H)       discretized input transition
      C    (D, H)       output map
      h0   (B, D, H)
    returns y (B, L, D), h_last (B, D, H)

Discretization (ZOH, as in the paper Sec. 3.1):
  Ābar = exp(Δ A);  B̄bar = Δ B   (the standard Mamba simplification of ZOH
  for B, which the paper also adopts: B̄_t = Δ_t B_t).
"""

import jax
import jax.numpy as jnp


def selective_scan_ref(x, delta, A, Bmat, C, h0):
    """Reference S6 selective scan via lax.scan over time.

    Returns (y, h_last): y (B, L, D), h_last (B, D, H).
    """
    B_, L, D = x.shape
    H = A.shape[1]
    assert A.shape == (D, H)
    assert delta.shape == (B_, L, D)
    assert Bmat.shape == (B_, L, H)
    assert C.shape == (B_, L, H)
    assert h0.shape == (B_, D, H)

    def step(h, inp):
        x_t, d_t, b_t, c_t = inp          # (B,D) (B,D) (B,H) (B,H)
        abar = jnp.exp(d_t[..., None] * A[None])          # (B, D, H)
        bbar_x = (d_t * x_t)[..., None] * b_t[:, None, :]  # (B, D, H)
        h = abar * h + bbar_x                              # (B, D, H)
        y_t = jnp.einsum("bdh,bh->bd", h, c_t)             # (B, D)
        return h, y_t

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(delta, 1, 0),
        jnp.moveaxis(Bmat, 1, 0),
        jnp.moveaxis(C, 1, 0),
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_last


def s4_scan_ref(x, Abar, Bbar, C, h0):
    """Reference LTI diagonal SSM scan (S4 after discretization).

    Returns (y, h_last): y (B, L, D), h_last (B, D, H).
    """
    B_, L, D = x.shape
    H = Abar.shape[1]
    assert Abar.shape == (D, H) and Bbar.shape == (D, H) and C.shape == (D, H)
    assert h0.shape == (B_, D, H)

    def step(h, x_t):
        h = Abar[None] * h + Bbar[None] * x_t[..., None]   # (B, D, H)
        y_t = jnp.einsum("bdh,dh->bd", h, C)
        return h, y_t

    h_last, ys = jax.lax.scan(step, h0, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(ys, 0, 1), h_last


def s4_conv_ref(x, Abar, Bbar, C, h0):
    """Alternative S4 oracle via the convolutional form (Eq. 3 of the paper).

    y_n = sum_{m<=n} C Ābar^{n-m} B̄bar x_m  (+ contribution of h0).
    Quadratic in L — used only as a second, independently-derived oracle in
    tests (it shares no code path with s4_scan_ref).
    """
    B_, L, D = x.shape
    n = jnp.arange(L)
    # kern[l, d] = sum_h C[d,h] * Abar[d,h]^l * Bbar[d,h]
    powers = Abar[None, :, :] ** n[:, None, None]            # (L, D, H)
    kern = jnp.einsum("ldh,dh,dh->ld", powers, C, Bbar)      # (L, D)

    idx = n[:, None] - n[None, :]                            # (L, L)
    mask = idx >= 0
    gath = jnp.where(mask[:, :, None], kern[jnp.clip(idx, 0), :], 0.0)  # (L,L,D)
    y = jnp.einsum("bmd,nmd->bnd", x, gath)
    # initial-state contribution: C Ābar^{n+1} h0
    hpow = Abar[None, :, :] ** (n[:, None, None] + 1)        # (L, D, H)
    y0 = jnp.einsum("bdh,ldh,dh->bld", h0, hpow, C)
    return y + y0
