"""Layer-1 Pallas kernel: LTI diagonal SSM scan (deep S4, paper Sec. 3.1).

Same kernel architecture as selective_scan.py but with time-invariant,
per-channel (Ābar, B̄bar, C): the (TILE_D, H) parameter tiles are loaded into
the VMEM block once per grid step and reused across all L time steps —
exactly the data-reuse structure a TPU kernel wants (and what the
convolutional form of S4 exploits on parallel hardware).

Backward recomputes the hidden trajectory (rematerialization) like the S6
kernel. Correctness is pinned against BOTH ref.s4_scan_ref (recurrent oracle)
and ref.s4_conv_ref (independently-derived convolutional oracle).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .selective_scan import INTERPRET, _tile_d


def _fwd_kernel(x_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hl_ref):
    L = x_ref.shape[1]
    Ab = a_ref[...]                     # (TD, H) resident across the scan
    Bb = b_ref[...]
    Cc = c_ref[...]

    def body(t, h):
        x_t = x_ref[0, t, :]                            # (TD,)
        h = Ab * h + Bb * x_t[:, None]                  # (TD, H)
        y_ref[0, t, :] = jnp.sum(h * Cc, axis=1)        # (TD,)
        return h

    hl_ref[0] = jax.lax.fori_loop(0, L, body, h0_ref[0])


def _fwd_call(x, Abar, Bbar, C, h0):
    B_, L, D = x.shape
    H = Abar.shape[1]
    TD = _tile_d(D)
    grid = (B_, D // TD)
    par = pl.BlockSpec((TD, H), lambda b, d: (d, 0))
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),
            par, par, par,
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_, L, D), x.dtype),
            jax.ShapeDtypeStruct((B_, D, H), x.dtype),
        ],
        interpret=INTERPRET,
    )(x, Abar, Bbar, C, h0)


def _bwd_kernel(x_ref, a_ref, b_ref, c_ref, h0_ref, gy_ref, ghl_ref,
                dx_ref, da_ref, db_ref, dc_ref, dh0_ref, hbuf_ref):
    """Adjoint of the LTI scan (one batch × channel-tile grid step).

        λ_t = g_t C + Ābar λ_{t+1};   dx_t = Σ_h λ B̄bar
        dĀ += λ_t ⊙ h_{t-1};  dB̄ += λ_t x_t;  dC += g_t h_t;  dh0 = Ābar λ_1
    """
    L = x_ref.shape[1]
    Ab = a_ref[...]
    Bb = b_ref[...]
    Cc = c_ref[...]

    def fwd_body(t, h):
        h = Ab * h + Bb * x_ref[0, t, :][:, None]
        hbuf_ref[0, t] = h
        return h

    jax.lax.fori_loop(0, L, fwd_body, h0_ref[0])

    zero = jnp.zeros_like(Ab)

    def bwd_body(i, carry):
        lam, dA, dB, dC = carry
        t = L - 1 - i
        x_t = x_ref[0, t, :]
        g_t = gy_ref[0, t, :]
        h_t = hbuf_ref[0, t]
        h_prev = jnp.where(t == 0, h0_ref[0], hbuf_ref[0, jnp.maximum(t - 1, 0)])
        lam = lam + g_t[:, None] * Cc
        dC = dC + g_t[:, None] * h_t
        dx_ref[0, t, :] = jnp.sum(lam * Bb, axis=1)
        dA = dA + lam * h_prev
        dB = dB + lam * x_t[:, None]
        lam = Ab * lam
        return lam, dA, dB, dC

    lam, dA, dB, dC = jax.lax.fori_loop(
        0, L, bwd_body, (ghl_ref[0], zero, zero, zero)
    )
    da_ref[0] = dA
    db_ref[0] = dB
    dc_ref[0] = dC
    dh0_ref[0] = lam


def _bwd_call(x, Abar, Bbar, C, h0, gy, ghl):
    B_, L, D = x.shape
    H = Abar.shape[1]
    TD = _tile_d(D)
    grid = (B_, D // TD)
    par = pl.BlockSpec((TD, H), lambda b, d: (d, 0))
    pout = pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0))
    outs = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),
            par, par, par,
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),
            pout, pout, pout,
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, L, TD, H), lambda b, d: (b, 0, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_, L, D), x.dtype),
            jax.ShapeDtypeStruct((B_, D, H), x.dtype),   # dA per batch
            jax.ShapeDtypeStruct((B_, D, H), x.dtype),   # dB per batch
            jax.ShapeDtypeStruct((B_, D, H), x.dtype),   # dC per batch
            jax.ShapeDtypeStruct((B_, D, H), x.dtype),   # dh0
            jax.ShapeDtypeStruct((B_, L, D, H), x.dtype),  # hbuf (discarded)
        ],
        interpret=INTERPRET,
    )(x, Abar, Bbar, C, h0, gy, ghl)
    dx, dA_b, dB_b, dC_b, dh0, _ = outs
    return dx, jnp.sum(dA_b, 0), jnp.sum(dB_b, 0), jnp.sum(dC_b, 0), dh0


@jax.custom_vjp
def s4_scan(x, Abar, Bbar, C, h0):
    """LTI diagonal SSM scan. Returns (y, h_last). See ref.s4_scan_ref."""
    return _fwd_call(x, Abar, Bbar, C, h0)


def _vjp_fwd(x, Abar, Bbar, C, h0):
    out = _fwd_call(x, Abar, Bbar, C, h0)
    return out, (x, Abar, Bbar, C, h0)


def _vjp_bwd(res, g):
    gy, ghl = g
    return _bwd_call(*res, gy, ghl)


s4_scan.defvjp(_vjp_fwd, _vjp_bwd)
