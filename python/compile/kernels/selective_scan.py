"""Layer-1 Pallas kernel: S6 selective scan (the Mamba compute hot-spot).

Forward and backward are hand-written Pallas kernels joined by
`jax.custom_vjp`, so the whole train-step graph (L2) lowers through the same
HLO pipeline and autodiff never has to differentiate through `pallas_call`.

TPU mapping of the paper's CUDA kernel (DESIGN.md §Hardware-Adaptation):
  * grid = (B, D // TILE_D): each grid step owns a channel tile; its working
    set — the (L, TILE_D) x/delta tiles, the (L, H) B/C tiles and the
    (TILE_D, H) hidden-state carry — is the VMEM-resident block, expressed
    with BlockSpecs instead of CUDA threadblock shared memory.
  * the discretized Ābar_t = exp(Δ_t A) is (re)computed inside the scan body
    rather than materialized as an (B, L, D, H) tensor in HBM — the same
    memory-traffic insight as the paper's recomputation trick.
  * the backward kernel recomputes the hidden-state trajectory into a kernel
    buffer instead of saving it from the forward pass (activation
    rematerialization at the kernel level).

CPU execution uses interpret=True (the CPU PJRT plugin cannot run Mosaic
custom-calls); numerics are identical, and correctness is pinned against
ref.selective_scan_ref by pytest + hypothesis sweeps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT target; flip to False for a real TPU build.


def _tile_d(D: int) -> int:
    """Channel tile: largest power-of-two divisor of D, capped at 32.

    Chosen so a grid step's VMEM block (x, delta tiles (L,TILE_D), B/C tiles
    (L,H), carry (TILE_D,H)) stays ≈O(100KB) for the shapes we export.
    """
    t = 1
    while t < 32 and D % (t * 2) == 0:
        t *= 2
    return t


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(x_ref, d_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hl_ref):
    """One (batch, channel-tile) grid step: scan L steps over time.

    Refs (leading batch block dim of size 1 squeezed by indexing [0]):
      x_ref, d_ref : (1, L, TD)   input / step size
      a_ref        : (TD, H)      continuous A (time-invariant)
      b_ref, c_ref : (1, L, H)    input-dependent B_t / C_t
      h0_ref       : (1, TD, H)   initial hidden state
      y_ref        : (1, L, TD)   output
      hl_ref       : (1, TD, H)   final hidden state (for decode/prefill)
    """
    L = x_ref.shape[1]
    A = a_ref[...]                      # (TD, H) — stays resident all L steps
    h_init = h0_ref[0]                  # (TD, H)

    def body(t, h):
        x_t = x_ref[0, t, :]            # (TD,)
        d_t = d_ref[0, t, :]            # (TD,)
        b_t = b_ref[0, t, :]            # (H,)
        c_t = c_ref[0, t, :]            # (H,)
        abar = jnp.exp(d_t[:, None] * A)                   # (TD, H)
        h = abar * h + (d_t * x_t)[:, None] * b_t[None, :]  # (TD, H)
        y_ref[0, t, :] = h @ c_t                            # (TD,)
        return h

    h_last = jax.lax.fori_loop(0, L, body, h_init)
    hl_ref[0] = h_last


def _fwd_call(x, delta, A, Bmat, C, h0):
    B_, L, D = x.shape
    H = A.shape[1]
    TD = _tile_d(D)
    grid = (B_, D // TD)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),   # x
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),   # delta
            pl.BlockSpec((TD, H), lambda b, d: (d, 0)),         # A
            pl.BlockSpec((1, L, H), lambda b, d: (b, 0, 0)),    # Bmat
            pl.BlockSpec((1, L, H), lambda b, d: (b, 0, 0)),    # C
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),   # y
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),   # h_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_, L, D), x.dtype),
            jax.ShapeDtypeStruct((B_, D, H), x.dtype),
        ],
        interpret=INTERPRET,
    )(x, delta, A, Bmat, C, h0)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _bwd_kernel(x_ref, d_ref, a_ref, b_ref, c_ref, h0_ref, gy_ref, ghl_ref,
                dx_ref, dd_ref, da_ref, db_ref, dc_ref, dh0_ref, hbuf_ref):
    """Backward for one (batch, channel-tile) grid step.

    Pass 1 recomputes the hidden trajectory h_t into hbuf (kernel-level
    rematerialization; the forward pass saves nothing but its inputs).
    Pass 2 runs the adjoint recurrence in reverse:
        λ_t = g_t ⊗ C_t + Ābar_{t+1} ⊙ λ_{t+1}        (+ ghl at t = L)
        dx_t[d]   = Δ_t[d] Σ_h λ[d,h] B_t[h]
        dΔ_t[d]   = Σ_h λ[d,h] (A Ābar_t h_{t-1} + B_t x_t)[d,h]
        dA[d,h]  += λ[d,h] Δ_t[d] Ābar_t[d,h] h_{t-1}[d,h]
        dB_t[h]   = Σ_d λ[d,h] Δ_t[d] x_t[d]           (per-tile partial)
        dC_t[h]   = Σ_d g_t[d] h_t[d,h]                (per-tile partial)
        dh0       = Ābar_1 ⊙ λ_1
    dB/dC are summed over channel tiles and dA over batch outside the kernel.
    """
    L = x_ref.shape[1]
    A = a_ref[...]

    # ---- pass 1: recompute h trajectory ------------------------------------
    def fwd_body(t, h):
        x_t = x_ref[0, t, :]
        d_t = d_ref[0, t, :]
        b_t = b_ref[0, t, :]
        abar = jnp.exp(d_t[:, None] * A)
        h = abar * h + (d_t * x_t)[:, None] * b_t[None, :]
        hbuf_ref[0, t] = h
        return h

    jax.lax.fori_loop(0, L, fwd_body, h0_ref[0])

    # ---- pass 2: reverse adjoint scan ---------------------------------------
    da_init = jnp.zeros_like(A)
    lam_init = ghl_ref[0]               # (TD, H) adjoint of h_last

    def bwd_body(i, carry):
        lam, dA = carry
        t = L - 1 - i
        x_t = x_ref[0, t, :]
        d_t = d_ref[0, t, :]
        b_t = b_ref[0, t, :]
        c_t = c_ref[0, t, :]
        g_t = gy_ref[0, t, :]           # (TD,)
        h_t = hbuf_ref[0, t]            # (TD, H)
        h_prev = jnp.where(t == 0, h0_ref[0], hbuf_ref[0, jnp.maximum(t - 1, 0)])

        lam = lam + g_t[:, None] * c_t[None, :]             # (TD, H)
        abar = jnp.exp(d_t[:, None] * A)
        # parameter/input grads at step t
        dc_ref[0, 0, t, :] = g_t @ h_t                       # (H,)
        dx_ref[0, t, :] = d_t * (lam @ b_t)                  # (TD,)
        dd_ref[0, t, :] = jnp.sum(
            lam * (A * abar * h_prev + b_t[None, :] * x_t[:, None]), axis=1
        )
        db_ref[0, 0, t, :] = (d_t * x_t) @ lam               # (H,)
        dA = dA + lam * d_t[:, None] * abar * h_prev
        lam = abar * lam                                     # push through Ābar_t
        return lam, dA

    lam_final, dA = jax.lax.fori_loop(0, L, bwd_body, (lam_init, da_init))
    da_ref[0] = dA
    dh0_ref[0] = lam_final


def _bwd_call(x, delta, A, Bmat, C, h0, gy, ghl):
    B_, L, D = x.shape
    H = A.shape[1]
    TD = _tile_d(D)
    ND = D // TD
    grid = (B_, ND)
    outs = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),   # x
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),   # delta
            pl.BlockSpec((TD, H), lambda b, d: (d, 0)),         # A
            pl.BlockSpec((1, L, H), lambda b, d: (b, 0, 0)),    # Bmat
            pl.BlockSpec((1, L, H), lambda b, d: (b, 0, 0)),    # C
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),   # h0
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),   # gy
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),   # ghl
        ],
        out_specs=[
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),       # dx
            pl.BlockSpec((1, L, TD), lambda b, d: (b, 0, d)),       # ddelta
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),       # dA (per b)
            pl.BlockSpec((1, 1, L, H), lambda b, d: (b, d, 0, 0)),  # dB partial
            pl.BlockSpec((1, 1, L, H), lambda b, d: (b, d, 0, 0)),  # dC partial
            pl.BlockSpec((1, TD, H), lambda b, d: (b, d, 0)),       # dh0
            pl.BlockSpec((1, L, TD, H), lambda b, d: (b, 0, d, 0)),  # hbuf
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_, L, D), x.dtype),
            jax.ShapeDtypeStruct((B_, L, D), x.dtype),
            jax.ShapeDtypeStruct((B_, D, H), x.dtype),
            jax.ShapeDtypeStruct((B_, ND, L, H), x.dtype),
            jax.ShapeDtypeStruct((B_, ND, L, H), x.dtype),
            jax.ShapeDtypeStruct((B_, D, H), x.dtype),
            jax.ShapeDtypeStruct((B_, L, D, H), x.dtype),
        ],
        interpret=INTERPRET,
    )(x, delta, A, Bmat, C, h0, gy, ghl)
    dx, dd, dA_b, dB_p, dC_p, dh0, _hbuf = outs
    dA = jnp.sum(dA_b, axis=0)          # reduce batch
    dB = jnp.sum(dB_p, axis=1)          # reduce channel tiles -> (B, L, H)
    dC = jnp.sum(dC_p, axis=1)
    return dx, dd, dA, dB, dC, dh0


# ---------------------------------------------------------------------------
# custom-vjp wrapper — public API
# ---------------------------------------------------------------------------

@jax.custom_vjp
def selective_scan(x, delta, A, Bmat, C, h0):
    """S6 selective scan. Returns (y, h_last). See ref.selective_scan_ref."""
    return _fwd_call(x, delta, A, Bmat, C, h0)


def _vjp_fwd(x, delta, A, Bmat, C, h0):
    y, hl = _fwd_call(x, delta, A, Bmat, C, h0)
    return (y, hl), (x, delta, A, Bmat, C, h0)


def _vjp_bwd(res, g):
    gy, ghl = g
    return _bwd_call(*res, gy, ghl)


selective_scan.defvjp(_vjp_fwd, _vjp_bwd)


@functools.partial(jax.jit, static_argnames=())
def selective_scan_jit(x, delta, A, Bmat, C, h0):
    return selective_scan(x, delta, A, Bmat, C, h0)
