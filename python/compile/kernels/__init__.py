"""Layer-1 Pallas kernels and their pure-jnp oracles."""
from .ref import selective_scan_ref, s4_scan_ref, s4_conv_ref  # noqa: F401
from .selective_scan import selective_scan  # noqa: F401
from .s4_scan import s4_scan  # noqa: F401
