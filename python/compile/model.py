"""Layer-2 assembly: (architecture, PEFT) -> pure functions for AOT export.

Produces, per variant:
  fwd(params_flat..., tokens)                     -> logits
  step(train..., frozen..., tokens, tgt, mask)    -> (loss, grads over train)
  decode(params..., token, conv_st, ssm_st)       -> (logits, conv_st', ssm_st')
  prefill(params..., tokens (B,C), conv_st, ssm_st)
                                                  -> (logits_last, conv', ssm')
Parameters travel as flat lists in sorted-name order; the AOT manifest records
the exact order/shapes so the Rust runtime is layout-agnostic.
"""

import jax
import jax.numpy as jnp

from . import peft as peft_mod
from .ssm import common as cm
from .ssm import hybrid, s4, s6
from .ssm.common import ArchSpec  # noqa: F401  (re-export)

FORWARDS = {
    "mamba1": s6.forward,
    "mamba2": s6.forward,
    "s4lm": s4.forward,
    "s4reg": s4.forward_reg,
    "hybrid": hybrid.forward,
}


def init_model(seed, spec, peft):
    rng = jax.random.PRNGKey(seed)
    if spec.kind in ("mamba1", "mamba2"):
        params = s6.init_params(rng, spec)
    elif spec.kind.startswith("s4"):
        params = s4.init_params(rng, spec)
    elif spec.kind == "hybrid":
        params = hybrid.init_params(rng, spec)
    else:
        raise ValueError(spec.kind)
    params, trainable = peft_mod.init_peft(jax.random.fold_in(rng, 1),
                                           params, spec, peft)
    return params, trainable


def forward_fn(spec, peft):
    fwd = FORWARDS[spec.kind]

    def f(params, x):
        eff = peft_mod.make_eff(params, peft)
        return fwd(params, eff, spec, x)

    return f


def loss_fn(spec, peft):
    f = forward_fn(spec, peft)

    if spec.is_reg:
        def loss(params, x, target, mask):
            y = f(params, x)
            # masked MSE, averaged over all tokens (paper Sec. 6.1)
            err = (y - target) ** 2 * mask[..., None]
            return jnp.sum(err) / jnp.maximum(jnp.sum(mask) * y.shape[-1], 1.0)
    else:
        def loss(params, tokens, targets, mask):
            logits = f(params, tokens)
            return cm.cross_entropy_loss(logits, targets, mask)

    return loss


def step_fn(spec, peft, trainable):
    """(train_dict, frozen_dict, batch...) -> (loss, grads over train)."""
    loss = loss_fn(spec, peft)
    tset = set(trainable)

    def step(train, frozen, x, targets, mask):
        def inner(train):
            params = {**frozen, **train}
            return loss(params, x, targets, mask)

        l, g = jax.value_and_grad(inner)(train)
        return l, g

    return step, tset


def decode_fn(spec, peft):
    assert spec.kind in ("mamba1", "mamba2")

    def decode(params, token, conv_states, ssm_states):
        eff = peft_mod.make_eff(params, peft)
        return s6.decode_step(params, eff, spec, token, conv_states, ssm_states)

    return decode


def _adapter_target_shape(spec, leaf):
    """Base-weight shape for a per-row adapter slot target."""
    H = 1 if spec.kind == "mamba2" else spec.d_state
    return {
        "Win_x": (spec.d_model, spec.d_inner),
        "Win_z": (spec.d_model, spec.d_inner),
        "xproj": (spec.d_inner, spec.dt_rank + 2 * spec.d_state),
        "dtproj.w": (spec.dt_rank, spec.d_inner),
        "Wout": (spec.d_inner, spec.d_model),
        "A_log": (spec.d_inner, H),
    }[leaf]


def adapter_operands(spec, B, rank, k):
    """Canonical per-row adapter operand list for the decode_adapters
    artifact: (name, shape, dtype) triples in exactly the order the
    executable takes them after (params..., token, conv_st, ssm_st).
    The manifest records this order so the Rust runtime stays
    layout-agnostic."""
    ops = [("scale", (B,), jnp.float32)]
    for i in range(spec.n_layer):
        pre = f"layers.{i}."
        for t in s6.LORA_SLOT_TARGETS:
            din, dout = _adapter_target_shape(spec, t)
            ops.append((pre + t + ".lora_a", (B, din, rank), jnp.float32))
            ops.append((pre + t + ".lora_b", (B, rank, dout), jnp.float32))
        for p in s6.SDT_SLOT_PARAMS:
            ops.append((pre + p + ".sdt_idx", (B, k), jnp.int32))
            ops.append((pre + p + ".sdt_val", (B, k), jnp.float32))
    return ops


def zero_adapter_operands(spec, B, rank, k):
    """All-zero operand dict (every row decodes the unmodified base)."""
    return {name: jnp.zeros(shape, dtype)
            for name, shape, dtype in adapter_operands(spec, B, rank, k)}


def decode_adapters_fn(spec, peft):
    """Unmerged multi-adapter decode: (params..., token, conv_st, ssm_st,
    adapter_operands...) -> (logits, conv_st', ssm_st'). One shared base
    dispatch; per-row LoRA/SDT deltas applied as a second pass."""
    assert spec.kind in ("mamba1", "mamba2")

    def decode(params, token, conv_states, ssm_states, adapters):
        eff = peft_mod.make_eff(params, peft)
        return s6.decode_step_adapters(params, eff, spec, token, conv_states,
                                       ssm_states, adapters)

    return decode


def prefill_fn(spec, peft):
    """Chunked prefill: (params..., tokens (B, C), conv_st, ssm_st)
    -> (logits_last, conv_st', ssm_st'). One dispatch scans C tokens and
    leaves the recurrent state ready for the next chunk or decode step."""
    assert spec.kind in ("mamba1", "mamba2")

    def prefill(params, tokens, conv_states, ssm_states):
        eff = peft_mod.make_eff(params, peft)
        return s6.prefill_chunk(params, eff, spec, tokens, conv_states,
                                ssm_states)

    return prefill
