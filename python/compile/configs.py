"""Variant registry: every (architecture × PEFT) artifact the benches need.

Sizes are scaled for the CPU testbed (DESIGN.md §Substitutions): the paper's
130M–2.8B pretrained checkpoints become 0.1M–1M-param models pretrained from
scratch by the Rust coordinator. Shapes (batch B, seq len L) are baked into
each artifact; the manifest records them.
"""

from .ssm.common import ArchSpec

# -- architecture presets ----------------------------------------------------
ARCHS = {
    "mamba1_xs": ArchSpec(kind="mamba1", d_model=64, n_layer=2, d_inner=128,
                          d_state=16, d_conv=4, dt_rank=4),
    "mamba1_s": ArchSpec(kind="mamba1", d_model=128, n_layer=4, d_inner=256,
                         d_state=16, d_conv=4, dt_rank=8),
    "mamba2_xs": ArchSpec(kind="mamba2", d_model=64, n_layer=2, d_inner=128,
                          d_state=16, d_conv=4, dt_rank=4),
    "s4reg": ArchSpec(kind="s4reg", d_model=64, n_layer=4, d_state=16),
    "s4reg_t": ArchSpec(kind="s4reg", d_model=64, n_layer=1, d_state=4),
    "s4lm": ArchSpec(kind="s4lm", d_model=64, n_layer=4, d_state=16),
    "hybrid_xs": ArchSpec(kind="hybrid", d_model=64, n_layer=4, d_inner=128,
                          d_state=16, d_conv=4, dt_rank=4, n_head=4),
}

# -- PEFT presets ------------------------------------------------------------
PEFTS = {
    "full": {"method": "full"},
    "lora_lin": {"method": "lora", "targets": ["linproj"], "rank": 8, "alpha": 8},
    "lora_ssm": {"method": "lora", "targets": ["ssm"], "rank": 8, "alpha": 8},
    "lora_both": {"method": "lora", "targets": ["both"], "rank": 8, "alpha": 8},
    "lora_out": {"method": "lora", "targets": ["out"], "rank": 8, "alpha": 8},
    "dora_lin": {"method": "dora", "targets": ["linproj"], "rank": 8, "alpha": 8},
    "dora_ssm": {"method": "dora", "targets": ["ssm"], "rank": 8, "alpha": 8},
    "dora_both": {"method": "dora", "targets": ["both"], "rank": 8, "alpha": 8},
    "bitfit": {"method": "bitfit"},
    "prompt": {"method": "prompt", "n_tokens": 16},
    "prefix": {"method": "prefix", "n_tokens": 4},
    "initstate": {"method": "initstate"},
    "addscan": {"method": "addscan"},
    "sdt": {"method": "sdt"},
    "sdtlora": {"method": "sdtlora", "rank": 4, "alpha": 4},
    # s4-specific LoRA targets (Fig. 2: LoRA on the SSM tensors themselves)
    "s4_lora_proj": {"method": "lora", "targets": ["s4w"], "rank": 4, "alpha": 4},
    "s4_lora_ssm": {"method": "lora", "targets": ["s4w", "A_log", "C"],
                    "rank": 2, "alpha": 2},
}

MAMBA1_PEFTS = ["full", "lora_lin", "lora_ssm", "lora_both", "lora_out",
                "dora_lin", "dora_ssm", "dora_both", "bitfit", "prompt",
                "prefix", "initstate", "addscan", "sdt", "sdtlora"]
MAMBA2_PEFTS = ["full", "lora_lin", "lora_ssm", "sdt", "sdtlora"]
S4REG_PEFTS = ["full", "s4_lora_proj", "s4_lora_ssm", "sdt", "sdtlora"]
S4LM_PEFTS = ["full", "s4_lora_proj", "sdt", "sdtlora"]
HYBRID_PEFTS = ["full", "lora_lin", "dora_lin", "bitfit", "prompt", "prefix",
                "addscan", "sdt", "sdtlora"]


def variants():
    """Yield dicts {name, arch_name, spec, peft_name, peft, B, L, decode}."""
    out = []

    def add(arch, pefts, B, L, decode_for=()):
        for p in pefts:
            out.append(dict(
                name=f"{arch}_{p}", arch=arch, spec=ARCHS[arch],
                peft_name=p, peft=PEFTS[p], B=B, L=L,
                decode=(p in decode_for)))

    add("mamba1_xs", MAMBA1_PEFTS, B=8, L=128, decode_for=("full",))
    add("mamba1_s", ["full", "sdtlora", "lora_lin"], B=8, L=192,
        decode_for=("full",))
    add("mamba2_xs", MAMBA2_PEFTS, B=8, L=128, decode_for=("full",))
    add("s4reg", S4REG_PEFTS, B=4, L=200)
    add("s4reg_t", ["full"], B=4, L=200)
    add("s4lm", S4LM_PEFTS, B=8, L=128)
    add("hybrid_xs", HYBRID_PEFTS, B=8, L=96)
    return out
