"""AOT pipeline: lower every variant to HLO text + write the manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the rust `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Per variant we emit:
  <name>.step.hlo.txt       (train..., frozen..., x, target, mask) -> (loss, grads...)
  <name>.fwd.hlo.txt        (train..., frozen..., x) -> logits
  <name>.decode.hlo.txt     (params..., token, conv_st, ssm_st) -> (logits, st')
  <name>.prefill<C>.hlo.txt (params..., tokens (B,C), conv_st, ssm_st)
                            -> (logits_last, st')   [decode variants only,
                            one artifact per chunk width C in PREFILL_WIDTHS]
  <name>.decode_adapters.hlo.txt
                            (params..., token, conv_st, ssm_st,
                             adapter_operands...) -> (logits, st')
                            [decode variants only: unmerged multi-adapter
                            decode — per-row LoRA/SDT delta operands]
  <name>.params.bin         f32-LE initial values, train-then-frozen order
plus a single artifacts/manifest.json describing all of it for the Rust
runtime (which is fully layout-agnostic).

Usage:  python -m compile.aot --out ../artifacts [--filter mamba1_xs]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model as model_mod

# Chunk widths exported for sequence-level prefill. The Rust planner covers
# a prompt with the largest-fitting chunks and finishes the remainder
# through the single-token decode artifact, so a couple of widths suffice.
PREFILL_WIDTHS = (16, 64)

# Per-row adapter slot sizes baked into the decode_adapters artifact:
# LoRA factors are zero-padded to rank ADAPTER_RANK (the largest rank the
# PEFT presets use) and each SDT sparse offset carries up to ADAPTER_K
# (index, value) pairs per SSM tensor — generous for the ~1% masks the
# paper trains. Adapters that do not fit fall back to the merged path.
ADAPTER_RANK = 8
ADAPTER_K = 256


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec_of(arr):
    return jax.ShapeDtypeStruct(arr.shape, arr.dtype)


def export_variant(v, outdir):
    spec, peft = v["spec"], v["peft"]
    B, L = v["B"], v["L"]
    params, trainable = model_mod.init_model(0, spec, peft)
    train = {k: params[k] for k in trainable}
    frozen = {k: v2 for k, v2 in params.items() if k not in train}
    tnames = sorted(train)
    fnames = sorted(frozen)

    if spec.is_reg:
        x_s = jax.ShapeDtypeStruct((B, L, spec.d_model), jnp.float32)
        t_s = jax.ShapeDtypeStruct((B, L, spec.d_model), jnp.float32)
    else:
        x_s = jax.ShapeDtypeStruct((B, L), jnp.int32)
        t_s = jax.ShapeDtypeStruct((B, L), jnp.int32)
    m_s = jax.ShapeDtypeStruct((B, L), jnp.float32)

    step, _ = model_mod.step_fn(spec, peft, trainable)

    def step_flat(*args):
        tr = dict(zip(tnames, args[:len(tnames)]))
        fr = dict(zip(fnames, args[len(tnames):len(tnames) + len(fnames)]))
        x, tgt, msk = args[len(tnames) + len(fnames):]
        loss, grads = step(tr, fr, x, tgt, msk)
        return (loss, *[grads[n] for n in tnames])

    fwd = model_mod.forward_fn(spec, peft)

    def fwd_flat(*args):
        tr = dict(zip(tnames, args[:len(tnames)]))
        fr = dict(zip(fnames, args[len(tnames):len(tnames) + len(fnames)]))
        return (fwd({**tr, **fr}, args[-1]),)

    arg_specs = [spec_of(train[n]) for n in tnames] + \
                [spec_of(frozen[n]) for n in fnames]

    files = {}
    step_hlo = to_hlo_text(jax.jit(step_flat).lower(*arg_specs, x_s, t_s, m_s))
    files["step"] = f"{v['name']}.step.hlo.txt"
    open(os.path.join(outdir, files["step"]), "w").write(step_hlo)

    fwd_hlo = to_hlo_text(jax.jit(fwd_flat).lower(*arg_specs, x_s))
    files["fwd"] = f"{v['name']}.fwd.hlo.txt"
    open(os.path.join(outdir, files["fwd"]), "w").write(fwd_hlo)

    adapter_meta = None
    if v["decode"]:
        dec = model_mod.decode_fn(spec, peft)
        anames = tnames + fnames

        def dec_flat(*args):
            p = dict(zip(anames, args[:len(anames)]))
            token, conv_st, ssm_st = args[len(anames):]
            return dec(p, token, conv_st, ssm_st)

        tok_s = jax.ShapeDtypeStruct((B,), jnp.int32)
        conv_s = jax.ShapeDtypeStruct(
            (spec.n_layer, B, spec.d_conv - 1, spec.d_inner), jnp.float32)
        ssm_s = jax.ShapeDtypeStruct(
            (spec.n_layer, B, spec.d_inner, spec.d_state), jnp.float32)
        dec_hlo = to_hlo_text(jax.jit(dec_flat).lower(*arg_specs, tok_s,
                                                      conv_s, ssm_s))
        files["decode"] = f"{v['name']}.decode.hlo.txt"
        open(os.path.join(outdir, files["decode"]), "w").write(dec_hlo)

        pf = model_mod.prefill_fn(spec, peft)

        def pf_flat(*args):
            p = dict(zip(anames, args[:len(anames)]))
            toks, conv_st, ssm_st = args[len(anames):]
            return pf(p, toks, conv_st, ssm_st)

        prefill_files = {}
        for c in PREFILL_WIDTHS:
            toks_s = jax.ShapeDtypeStruct((B, c), jnp.int32)
            pf_hlo = to_hlo_text(jax.jit(pf_flat).lower(*arg_specs, toks_s,
                                                        conv_s, ssm_s))
            fname = f"{v['name']}.prefill{c}.hlo.txt"
            open(os.path.join(outdir, fname), "w").write(pf_hlo)
            prefill_files[str(c)] = fname
        files["prefill"] = prefill_files

        # unmerged multi-adapter decode: same base batch, plus per-row
        # LoRA/SDT delta operands appended after the state inputs
        deca = model_mod.decode_adapters_fn(spec, peft)
        ops = model_mod.adapter_operands(spec, B, ADAPTER_RANK, ADAPTER_K)

        def deca_flat(*args):
            p = dict(zip(anames, args[:len(anames)]))
            token, conv_st, ssm_st = args[len(anames):len(anames) + 3]
            ad = {name: arr for (name, _, _), arr
                  in zip(ops, args[len(anames) + 3:])}
            return deca(p, token, conv_st, ssm_st, ad)

        op_specs = [jax.ShapeDtypeStruct(shape, dtype)
                    for _, shape, dtype in ops]
        deca_hlo = to_hlo_text(jax.jit(deca_flat).lower(
            *arg_specs, tok_s, conv_s, ssm_s, *op_specs))
        files["decode_adapters"] = f"{v['name']}.decode_adapters.hlo.txt"
        open(os.path.join(outdir, files["decode_adapters"]), "w").write(deca_hlo)
        adapter_meta = {
            "rank": ADAPTER_RANK, "k": ADAPTER_K,
            "operands": [
                {"name": n, "shape": list(shape),
                 "dtype": "i32" if dtype == jnp.int32 else "f32"}
                for n, shape, dtype in ops],
        }

    # ---- params.bin + manifest entry ---------------------------------------
    blob = bytearray()
    def entry(n, src):
        arr = np.asarray(src[n], np.float32)
        off = len(blob)
        blob.extend(arr.tobytes())
        return {"name": n, "shape": list(arr.shape), "offset": off,
                "numel": int(arr.size)}

    train_meta = [entry(n, train) for n in tnames]
    frozen_meta = [entry(n, frozen) for n in fnames]
    bin_name = f"{v['name']}.params.bin"
    open(os.path.join(outdir, bin_name), "wb").write(bytes(blob))

    out = {
        "name": v["name"],
        "arch": {
            "kind": spec.kind, "vocab": spec.vocab, "d_model": spec.d_model,
            "n_layer": spec.n_layer, "d_inner": spec.d_inner,
            "d_state": spec.d_state, "d_conv": spec.d_conv,
            "dt_rank": spec.dt_rank, "n_head": spec.n_head,
            "h_add": spec.h_add,
        },
        "peft": {"method": peft["method"],
                 "rank": peft.get("rank", 0),
                 # merge scale numerator; mirrors peft.make_eff's
                 # alpha default (= rank, i.e. scale 1.0)
                 "alpha": peft.get("alpha", peft.get("rank", 0)),
                 "targets": peft.get("targets", []),
                 "n_tokens": peft.get("n_tokens", 0)},
        "batch": {"B": B, "L": L},
        "reg": spec.is_reg,
        "files": files,
        "params_bin": bin_name,
        "train_params": train_meta,
        "frozen_params": frozen_meta,
    }
    if adapter_meta is not None:
        out["adapter_operands"] = adapter_meta
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--filter", default="")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    vs = configs.variants()
    if args.filter:
        vs = [v for v in vs if args.filter in v["name"]]
    if args.list:
        for v in vs:
            print(v["name"])
        return

    os.makedirs(args.out, exist_ok=True)
    entries = []
    for i, v in enumerate(vs):
        print(f"[{i + 1}/{len(vs)}] {v['name']}", flush=True)
        entries.append(export_variant(v, args.out))
    # version 3: decode variants additionally carry files.decode_adapters
    # (unmerged multi-adapter decode) + the adapter_operands layout table;
    # version 2 added files.prefill.{width} chunk artifacts
    manifest = {"version": 3, "variants": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} variants to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
