"""PEFT wiring (Layer 2): parameter transforms for every method in the paper.

A PEFT config is a plain dict:
    {"method": "lora", "targets": ["Win_x", "Win_z"], "rank": 8, "alpha": 8}
Methods (paper Sec. 3.2 / 4.1):
    full       — every parameter trainable
    lora       — low-rank adapters  W + (α/r)·A·B  on target matrices
    dora       — weight-decomposed LoRA:  m ⊙ (W+ΔW)/‖W+ΔW‖_col
    bitfit     — bias terms only (conv.b, dtproj.b; s4: beta)
    prompt     — soft prompt (M, Dm) prepended to the embedded input
    prefix     — per-layer soft prefixes (affix-tuning; outputs dropped)
    initstate  — per-layer trainable initial SSM state (Prop. 1 equivalent)
    addscan    — additional-scan: extra trainable state dims (Yoshimura'25)
    sdt        — Sparse Dimension Tuning: trainable = SSM tensors (A_log +
                 B/C columns of xproj; s4: A_log + C); the channel/state
                 masks of Alg. 1 are applied to GRADIENTS by the Rust
                 coordinator, so one artifact serves any selection.
    sdtlora    — SDT on the SSM module + LoRA on Wout (paper Sec. 6.2 setup)

Target-module shorthands (resolved per architecture):
    "linproj" → Win_x, Win_z           "out" → Wout
    "ssm"     → xproj, dtproj.w        "both" → linproj + ssm
LoRA naming: for weight "layers.0.Wout" the factors are
"layers.0.Wout.lora_a" (din, r) and "layers.0.Wout.lora_b" (r, dout);
DoRA adds "layers.0.Wout.dora_m" (dout,).
"""

import jax
import jax.numpy as jnp

TARGET_GROUPS = {
    "linproj": ["Win_x", "Win_z"],
    "out": ["Wout"],
    "ssm": ["xproj", "dtproj.w"],
    "both": ["Win_x", "Win_z", "xproj", "dtproj.w"],
    "s4w": ["W"],
    "s4ssm": [],  # S4 SSM tensors are tuned directly (sdt), not via LoRA here
    "head": ["head"],
}


def resolve_targets(spec, peft):
    """Expand target shorthands to concrete per-layer weight names."""
    names = []
    raw = peft.get("targets", [])
    leaves = []
    for t in raw:
        leaves.extend(TARGET_GROUPS.get(t, [t]))
    for i in range(spec.n_layer):
        if spec.kind == "hybrid" and i % 2 == 1:
            continue  # PEFT targets only the Mamba layers of the hybrid
        for leaf in leaves:
            if leaf in ("head",):
                continue
            names.append(f"layers.{i}.{leaf}")
    if "head" in leaves:
        names.append("head")
    return names


def init_peft(rng, params, spec, peft):
    """Add PEFT parameters to `params`; return (params, trainable_names)."""
    method = peft["method"]
    params = dict(params)
    ks = iter(jax.random.split(rng, 4 * max(len(params), 8)))
    trainable = []

    def add_lora(names, rank):
        for n in names:
            W = params[n]
            a = 0.02 * jax.random.normal(next(ks), (W.shape[0], rank))
            b = jnp.zeros((rank, W.shape[1]))
            params[n + ".lora_a"] = a
            params[n + ".lora_b"] = b
            trainable.extend([n + ".lora_a", n + ".lora_b"])

    if method == "full":
        trainable = list(params.keys())
    elif method == "lora":
        add_lora(resolve_targets(spec, peft), peft.get("rank", 8))
    elif method == "dora":
        names = resolve_targets(spec, peft)
        add_lora(names, peft.get("rank", 8))
        for n in names:
            params[n + ".dora_m"] = jnp.linalg.norm(params[n], axis=0)
            trainable.append(n + ".dora_m")
    elif method == "bitfit":
        for n in params:
            if n.endswith("conv.b") or n.endswith("dtproj.b") or n.endswith("beta"):
                trainable.append(n)
    elif method == "prompt":
        M = peft.get("n_tokens", 16)
        params["prompt"] = 0.02 * jax.random.normal(next(ks), (M, spec.d_model))
        trainable = ["prompt"]
    elif method == "prefix":
        M = peft.get("n_tokens", 4)
        for i in range(spec.n_layer):
            if spec.kind == "hybrid" and i % 2 == 1:
                continue
            n = f"layers.{i}.prefix"
            params[n] = 0.02 * jax.random.normal(next(ks), (M, spec.d_model))
            trainable.append(n)
    elif method == "initstate":
        dim = spec.d_model if spec.kind.startswith("s4") else spec.d_inner
        for i in range(spec.n_layer):
            if spec.kind == "hybrid" and i % 2 == 1:
                continue
            n = f"layers.{i}.h0"
            params[n] = jnp.zeros((dim, spec.d_state))
            trainable.append(n)
    elif method == "addscan":
        Ha = spec.h_add
        for i in range(spec.n_layer):
            if spec.kind == "hybrid" and i % 2 == 1:
                continue
            pre = f"layers.{i}."
            params[pre + "A_log_add"] = jnp.log(
                jnp.full((spec.d_inner, Ha), float(spec.d_state + 1)))
            params[pre + "xproj_add"] = jnp.zeros((spec.d_inner, 2 * Ha))
            trainable.extend([pre + "A_log_add", pre + "xproj_add"])
    elif method in ("sdt", "sdtlora"):
        for i in range(spec.n_layer):
            if spec.kind == "hybrid" and i % 2 == 1:
                continue
            pre = f"layers.{i}."
            if spec.kind.startswith("s4"):
                trainable.extend([pre + "A_log", pre + "C"])
            else:
                trainable.extend([pre + "A_log", pre + "xproj"])
        if method == "sdtlora":
            names = []
            for i in range(spec.n_layer):
                if spec.kind == "hybrid" and i % 2 == 1:
                    continue
                names.append(
                    f"layers.{i}.W" if spec.kind.startswith("s4")
                    else f"layers.{i}.Wout")
            add_lora(names, peft.get("rank", 4))
    else:
        raise ValueError(f"unknown PEFT method {method!r}")
    return params, sorted(set(trainable))


def make_eff(params, peft):
    """Effective-weight resolver used by all model forwards."""
    scale = peft.get("alpha", peft.get("rank", 8)) / max(peft.get("rank", 8), 1)

    def eff(name):
        W = params[name]
        if name + ".lora_a" in params:
            W = W + scale * (params[name + ".lora_a"] @ params[name + ".lora_b"])
            if name + ".dora_m" in params:
                norm = jnp.linalg.norm(W, axis=0, keepdims=True)
                W = params[name + ".dora_m"][None, :] * W / (norm + 1e-6)
        return W

    return eff


def merge_lora(params, peft):
    """Fold LoRA/DoRA factors into base weights (post-training, for decode)."""
    eff = make_eff(params, peft)
    merged = {}
    for n, v in params.items():
        if ".lora_a" in n or ".lora_b" in n or ".dora_m" in n:
            continue
        merged[n] = eff(n) if (n + ".lora_a") in params else v
    return merged
