"""Jamba-like hybrid model: interleaved Mamba and Transformer blocks.

Layer schedule: even layers are Mamba-I blocks (ssm.s6.block), odd layers are
causal multi-head attention blocks with a gated-MLP, mirroring Jamba's
interleave (Lieber et al., 2025) at small scale. As in the paper's Jamba
experiments, PEFT methods target ONLY the Mamba layers; attention/MLP
parameters stay frozen (they are still listed in the manifest so the Rust
side can verify the frozen partition).

Attention layer params (prefix "layers.{i}."):
  attn_norm.w (Dm,), Wq/Wk/Wv/Wo (Dm, Dm),
  mlp_norm.w (Dm,), Wmlp_up (Dm, 4Dm), Wmlp_gate (Dm, 4Dm), Wmlp_down (4Dm, Dm)
"""

import jax
import jax.numpy as jnp

from . import common as cm
from . import s6


def is_attn_layer(i: int) -> bool:
    return i % 2 == 1


def init_params(rng, spec):
    # start from full mamba params, replace odd layers with attention blocks
    p = s6.init_params(rng, spec)
    ks = iter(jax.random.split(jax.random.fold_in(rng, 7), 8 * spec.n_layer))
    Dm = spec.d_model
    for i in range(spec.n_layer):
        if not is_attn_layer(i):
            continue
        pre = f"layers.{i}."
        for k in list(p):
            if k.startswith(pre):
                del p[k]
        p[pre + "attn_norm.w"] = jnp.ones((Dm,))
        for w in ("Wq", "Wk", "Wv", "Wo"):
            p[pre + w] = cm.glorot(next(ks), (Dm, Dm))
        p[pre + "mlp_norm.w"] = jnp.ones((Dm,))
        p[pre + "Wmlp_up"] = cm.glorot(next(ks), (Dm, 4 * Dm))
        p[pre + "Wmlp_gate"] = cm.glorot(next(ks), (Dm, 4 * Dm))
        p[pre + "Wmlp_down"] = cm.glorot(next(ks), (4 * Dm, Dm))
    return p


def attn_block(params, pre, spec, u):
    """Causal MHA + gated MLP, both with residuals. u (B, L, Dm)."""
    Bsz, L, Dm = u.shape
    nh = spec.n_head
    hd = Dm // nh
    x = cm.rmsnorm(u, params[pre + "attn_norm.w"])
    q = (x @ params[pre + "Wq"]).reshape(Bsz, L, nh, hd)
    k = (x @ params[pre + "Wk"]).reshape(Bsz, L, nh, hd)
    v = (x @ params[pre + "Wv"]).reshape(Bsz, L, nh, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((L, L), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(Bsz, L, Dm)
    u = u + o @ params[pre + "Wo"]
    x = cm.rmsnorm(u, params[pre + "mlp_norm.w"])
    h = cm.silu(x @ params[pre + "Wmlp_gate"]) * (x @ params[pre + "Wmlp_up"])
    return u + h @ params[pre + "Wmlp_down"]


def forward(params, eff, spec, tokens):
    x = params["embed"][tokens]
    if "prompt" in params:
        P = params["prompt"]
        x = jnp.concatenate([jnp.tile(P[None], (x.shape[0], 1, 1)), x], axis=1)
    for i in range(spec.n_layer):
        pre = f"layers.{i}."
        if is_attn_layer(i):
            x = attn_block(params, pre, spec, x)
        else:
            x, _ = s6.block(params, eff, pre, spec, x)
    x = cm.rmsnorm(x, params["norm_f.w"])
    logits = x @ eff("head")
    if "prompt" in params:
        logits = logits[:, params["prompt"].shape[0]:, :]
    return logits
