"""Shared building blocks for the SSM model zoo (Layer 2)."""

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """Architecture hyperparameters for one model variant.

    kind: "mamba1" | "mamba2" | "s4lm" | "s4reg" | "hybrid"
    """

    kind: str
    vocab: int = 258          # 256 bytes + BOS(256) + PAD(257)
    d_model: int = 64
    n_layer: int = 2
    d_inner: int = 128        # mamba expansion (2x d_model)
    d_state: int = 16         # H
    d_conv: int = 4           # causal conv width (mamba)
    dt_rank: int = 4          # R (low-rank Δ projection)
    n_head: int = 4           # hybrid attention heads
    h_add: int = 4            # additional-scan extra states

    @property
    def is_reg(self) -> bool:
        return self.kind == "s4reg"


def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def silu(x):
    return x * jax.nn.sigmoid(x)


def softplus(x):
    return jax.nn.softplus(x)


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x (B, L, D), w (K, D), b (D,)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # small K: sum of K shifted slices — XLA fuses this into one loop.
    L = x.shape[1]
    y = jnp.zeros_like(x)
    for k in range(K):
        y = y + pad[:, k:k + L, :] * w[k][None, None, :]
    return y + b[None, None, :]


def causal_conv1d_carry(x, conv_state, w, b):
    """Depthwise causal conv over a chunk, carrying input state.

    Chunked-prefill variant of `causal_conv1d`: instead of zero-padding the
    left edge, the window starts from the last K-1 inputs of the previous
    chunk (oldest first), exactly like `conv1d_step` does one token at a
    time. x (B, C, D); conv_state (B, K-1, D).
    Returns (y (B, C, D), new_conv_state (B, K-1, D)).
    """
    K = w.shape[0]
    C = x.shape[1]
    window = jnp.concatenate([conv_state, x], axis=1)  # (B, K-1+C, D)
    y = jnp.zeros_like(x)
    for k in range(K):
        y = y + window[:, k:k + C, :] * w[k][None, None, :]
    return y + b[None, None, :], window[:, C:, :]


def conv1d_step(x_t, conv_state, w, b):
    """Single-token causal conv given the last K-1 inputs.

    x_t (B, D); conv_state (B, K-1, D) holding previous inputs (oldest first).
    Returns (y_t (B, D), new_conv_state).
    """
    K = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,D)
    y = jnp.einsum("bkd,kd->bd", window, w) + b[None, :]
    return y, window[:, 1:, :]


def glorot(rng, shape, scale=1.0):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    fan_out = shape[-1]
    lim = scale * (6.0 / (fan_in + fan_out)) ** 0.5
    return jax.random.uniform(rng, shape, jnp.float32, -lim, lim)


def init_a_log(rng, d, h):
    """S4D-real style init: A = -(1..H) per state, shared over channels."""
    base = jnp.tile(jnp.arange(1, h + 1, dtype=jnp.float32)[None, :], (d, 1))
    jitter = 0.1 * jax.random.uniform(rng, (d, h))
    return jnp.log(base + jitter)


def init_log_dt(rng, d, lo=1e-3, hi=1e-1):
    u = jax.random.uniform(rng, (d,))
    return jnp.log(lo) + u * (jnp.log(hi) - jnp.log(lo))


def cross_entropy_loss(logits, targets, mask):
    """Masked token-level cross entropy.

    logits (B, L, V); targets (B, L) int32; mask (B, L) f32.
    """
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    total = jnp.sum(mask)
    return jnp.sum(nll * mask) / jnp.maximum(total, 1.0)


def split_names(rng, n):
    return list(jax.random.split(rng, n))
