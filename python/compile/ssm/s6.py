"""Mamba-I block and LM (S6 selective SSM), paper Sec. 3.1.

Parameter names (layer i prefix "layers.{i}."):
  norm.w        (Dm,)        RMSNorm weight
  Win_x         (Dm, Di)     input projection, SSM branch  (paper W_in,x)
  Win_z         (Dm, Di)     input projection, gate branch (paper W_in,z)
  conv.w        (K, Di)      depthwise causal conv
  conv.b        (Di,)
  xproj         (Di, R+2H)   x_proj: [Δ-low | B | C] columns (paper W_Δ,↓ / W_B / W_C)
  dtproj.w      (R, Di)      dt_proj weight (paper W_Δ,↑)
  dtproj.b      (Di,)        Δ bias β_Δ
  A_log         (Di, H)      A = -exp(A_log)  (Mamba-II: (Di, 1) scalar per channel)
  Dskip         (Di,)        skip connection coefficient
  Wout          (Di, Dm)     output projection
Model-level: embed (V, Dm), norm_f.w (Dm,), head (Dm, V).

PEFT hooks: every weight matmul goes through `eff(name)` so LoRA/DoRA factors
apply; optional per-layer soft prefix ("layers.{i}.prefix"), initial SSM state
("layers.{i}.h0"), additional-scan extensions ("...A_log_add", "...xproj_add"),
and a model-level soft prompt ("prompt") are consumed here when present.
"""

import jax
import jax.numpy as jnp

from ..kernels import selective_scan
from . import common as cm


def init_params(rng, spec):
    p = {}
    ks = iter(jax.random.split(rng, 8 + 12 * spec.n_layer))
    p["embed"] = 0.02 * jax.random.normal(next(ks), (spec.vocab, spec.d_model))
    p["norm_f.w"] = jnp.ones((spec.d_model,))
    p["head"] = cm.glorot(next(ks), (spec.d_model, spec.vocab))
    h = 1 if spec.kind == "mamba2" else spec.d_state
    for i in range(spec.n_layer):
        pre = f"layers.{i}."
        p[pre + "norm.w"] = jnp.ones((spec.d_model,))
        p[pre + "Win_x"] = cm.glorot(next(ks), (spec.d_model, spec.d_inner))
        p[pre + "Win_z"] = cm.glorot(next(ks), (spec.d_model, spec.d_inner))
        p[pre + "conv.w"] = cm.glorot(next(ks), (spec.d_conv, spec.d_inner))
        p[pre + "conv.b"] = jnp.zeros((spec.d_inner,))
        p[pre + "xproj"] = cm.glorot(
            next(ks), (spec.d_inner, spec.dt_rank + 2 * spec.d_state))
        p[pre + "dtproj.w"] = cm.glorot(next(ks), (spec.dt_rank, spec.d_inner))
        # bias init so softplus(β) lands in [1e-3, 1e-1] like mamba's dt init
        p[pre + "dtproj.b"] = cm.init_log_dt(next(ks), spec.d_inner) + 0.55
        p[pre + "A_log"] = cm.init_a_log(next(ks), spec.d_inner, h)
        p[pre + "Dskip"] = jnp.ones((spec.d_inner,))
        p[pre + "Wout"] = cm.glorot(next(ks), (spec.d_inner, spec.d_model))
    return p


def _ssm_params(params, eff, pre, spec, x):
    """Compute (delta, A, Bmat, C) from the conv output x (B, L, Di)."""
    R, H = spec.dt_rank, spec.d_state
    xproj = eff(pre + "xproj")
    dbl = x @ xproj                                   # (B, L, R+2H)
    dt_low, Bm, C = dbl[..., :R], dbl[..., R:R + H], dbl[..., R + H:]
    delta = cm.softplus(dt_low @ eff(pre + "dtproj.w") + params[pre + "dtproj.b"])
    A = -jnp.exp(params[pre + "A_log"])               # (Di, H) or (Di, 1)
    if spec.kind == "mamba2":
        A = jnp.broadcast_to(A, (spec.d_inner, H))
    # additional-scan: append trainable extra state dimensions (Yoshimura'25)
    if pre + "A_log_add" in params:
        Ha = spec.h_add
        A = jnp.concatenate([A, -jnp.exp(params[pre + "A_log_add"])], axis=1)
        ext = x @ params[pre + "xproj_add"]           # (B, L, 2*Ha)
        Bm = jnp.concatenate([Bm, ext[..., :Ha]], axis=-1)
        C = jnp.concatenate([C, ext[..., Ha:]], axis=-1)
    return delta, A, Bm, C


def block(params, eff, pre, spec, u, h0=None):
    """One Mamba block. u (B, L, Dm) -> (B, L, Dm) with residual."""
    Bsz, L, _ = u.shape
    un = cm.rmsnorm(u, params[pre + "norm.w"])
    # per-layer soft prefix (affix-tuning): prepend M virtual inputs, drop
    # their outputs after the block (paper Sec. 3.2 / C.3).
    M = 0
    if pre + "prefix" in params:
        P = params[pre + "prefix"]                    # (M, Dm)
        M = P.shape[0]
        un = jnp.concatenate([jnp.tile(P[None], (Bsz, 1, 1)), un], axis=1)
    x = un @ eff(pre + "Win_x")
    z = un @ eff(pre + "Win_z")
    x = cm.silu(cm.causal_conv1d(x, params[pre + "conv.w"], params[pre + "conv.b"]))
    delta, A, Bm, C = _ssm_params(params, eff, pre, spec, x)
    if h0 is None:
        if pre + "h0" in params:                      # initial-state tuning
            h0v = jnp.tile(params[pre + "h0"][None], (Bsz, 1, 1))
            if A.shape[1] != h0v.shape[2]:            # additional-scan pad
                padh = A.shape[1] - h0v.shape[2]
                h0v = jnp.pad(h0v, ((0, 0), (0, 0), (0, padh)))
        else:
            h0v = jnp.zeros((Bsz, spec.d_inner, A.shape[1]), x.dtype)
    else:
        h0v = h0
    y, hl = selective_scan(x, delta, A, Bm, C, h0v)
    y = y + params[pre + "Dskip"][None, None, :] * x
    y = y * cm.silu(z)
    out = y @ eff(pre + "Wout")
    if M:
        out = out[:, M:, :]
    return u + out, hl


def forward(params, eff, spec, tokens):
    """tokens (B, L) int32 -> logits (B, L', V). L' = L + prompt length."""
    x = params["embed"][tokens]                       # (B, L, Dm)
    if "prompt" in params:                            # soft prompt tuning
        P = params["prompt"]
        x = jnp.concatenate([jnp.tile(P[None], (x.shape[0], 1, 1)), x], axis=1)
    for i in range(spec.n_layer):
        x, _ = block(params, eff, f"layers.{i}.", spec, x)
    x = cm.rmsnorm(x, params["norm_f.w"])
    logits = x @ eff("head")
    if "prompt" in params:
        logits = logits[:, params["prompt"].shape[0]:, :]
    return logits


def prefill_chunk(params, eff, spec, tokens, conv_states, ssm_states):
    """Sequence-level prefill: scan a whole (B, C) token chunk in one call.

    Semantically identical to C iterations of `decode_step` (same per-step
    recurrence inside `selective_scan`, same conv window as `conv1d_step`),
    but lowered as ONE program so a prompt costs ceil(P/C) dispatches
    instead of P. Only the last position's logits are returned — prefill
    consumes the prompt, it does not generate.

    tokens (B, C) int32; conv_states (n_layer, B, K-1, Di);
    ssm_states (n_layer, B, Di, H).
    Returns (logits_last (B, V), conv_states', ssm_states').
    """
    x = params["embed"][tokens]                       # (B, C, Dm)
    new_conv, new_ssm = [], []
    for i in range(spec.n_layer):
        pre = f"layers.{i}."
        un = cm.rmsnorm(x, params[pre + "norm.w"])
        xi = un @ eff(pre + "Win_x")
        z = un @ eff(pre + "Win_z")
        xi, cs = cm.causal_conv1d_carry(xi, conv_states[i], params[pre + "conv.w"],
                                        params[pre + "conv.b"])
        xi = cm.silu(xi)
        delta, A, Bm, C_ = _ssm_params(params, eff, pre, spec, xi)
        y, hl = selective_scan(xi, delta, A, Bm, C_, ssm_states[i])
        y = y + params[pre + "Dskip"][None, None, :] * xi
        y = y * cm.silu(z)
        x = x + y @ eff(pre + "Wout")
        new_conv.append(cs)
        new_ssm.append(hl)
    xl = cm.rmsnorm(x[:, -1, :], params["norm_f.w"])
    logits = xl @ eff("head")
    return logits, jnp.stack(new_conv), jnp.stack(new_ssm)


# Per-row adapter slots baked into the `decode_adapters` artifact: every
# matmul weight gets a (zero-padded) LoRA factor pair, and the SDT-trained
# SSM tensors get an index-set sparse offset. Rows whose adapter does not
# use a slot pass zeros (idx 0 / val 0 scatters are no-ops).
LORA_SLOT_TARGETS = ("Win_x", "Win_z", "xproj", "dtproj.w", "Wout")
SDT_SLOT_PARAMS = ("A_log", "xproj")


def decode_step_adapters(params, eff, spec, token, conv_states, ssm_states,
                         adapters):
    """Single-token decode over ONE shared base batch with per-row deltas.

    Unmerged multi-adapter serving (S-LoRA-style): the staged base weights
    are used once for the whole batch; each row then adds its own low-rank
    LoRA correction `scale · (x·a)·b` on the projection matmuls and an
    index-set sparse offset on the SDT-trained SSM tensors. Semantically
    identical to `decode_step` run per row with that row's merged weights.

    token (B,) int32; conv_states (n_layer, B, K-1, Di);
    ssm_states (n_layer, B, Di, H). `adapters` maps (see
    model.adapter_operands for the canonical order/shapes):
      "scale"                 (B,)        LoRA merge scale (alpha/rank) per row
      "<w>.lora_a"            (B, din, R) per-row LoRA A (zero-padded to R)
      "<w>.lora_b"            (B, R, dout)
      "<p>.sdt_idx"           (B, K) i32  flat indices into <p> (0-padded)
      "<p>.sdt_val"           (B, K) f32  offset values (0 on padding)
    Returns (logits (B, V), conv_states', ssm_states').
    """
    Bsz = token.shape[0]
    scale = adapters["scale"]                         # (B,)

    def mm(x, name):
        """x (B, din) through the per-row effective weight for `name`."""
        y = x @ eff(name)
        if name + ".lora_a" in adapters:
            lo = jnp.einsum("bi,bir->br", x, adapters[name + ".lora_a"])
            y = y + scale[:, None] * jnp.einsum(
                "br,bro->bo", lo, adapters[name + ".lora_b"])
        return y

    def sdt_delta(name):
        """Dense per-row offset (B, *shape) scattered from the index set."""
        W = params[name]
        idx = adapters[name + ".sdt_idx"]             # (B, K) flat indices
        val = adapters[name + ".sdt_val"]             # (B, K) values
        flat = jax.vmap(
            lambda i, v: jnp.zeros((W.size,), W.dtype).at[i].add(v))(idx, val)
        return flat.reshape((Bsz,) + W.shape)

    R, H = spec.dt_rank, spec.d_state
    x = params["embed"][token]                        # (B, Dm)
    new_conv, new_ssm = [], []
    for i in range(spec.n_layer):
        pre = f"layers.{i}."
        un = cm.rmsnorm(x, params[pre + "norm.w"])
        xi = mm(un, pre + "Win_x")
        z = mm(un, pre + "Win_z")
        xi, cs = cm.conv1d_step(xi, conv_states[i], params[pre + "conv.w"],
                                params[pre + "conv.b"])
        xi = cm.silu(xi)
        dbl = mm(xi, pre + "xproj")                   # (B, R+2H)
        if pre + "xproj.sdt_idx" in adapters:
            dbl = dbl + jnp.einsum("bd,bdo->bo", xi, sdt_delta(pre + "xproj"))
        dt_low, Bm, C = dbl[..., :R], dbl[..., R:R + H], dbl[..., R + H:]
        delta = cm.softplus(mm(dt_low, pre + "dtproj.w")
                            + params[pre + "dtproj.b"])
        A_log = params[pre + "A_log"][None]           # (1, Di, Ha)
        if pre + "A_log.sdt_idx" in adapters:
            A_log = A_log + sdt_delta(pre + "A_log")
        A = -jnp.exp(A_log)
        if spec.kind == "mamba2":
            A = jnp.broadcast_to(A, (Bsz, spec.d_inner, H))
        # selective_scan's A operand is batch-invariant, so the L=1
        # recurrence is inlined here with the per-row A (same math).
        h = ssm_states[i]                             # (B, Di, H)
        abar = jnp.exp(delta[:, :, None] * A)
        hl = abar * h + (delta * xi)[:, :, None] * Bm[:, None, :]
        y = jnp.einsum("bdh,bh->bd", hl, C)
        y = y + params[pre + "Dskip"][None, :] * xi
        y = y * cm.silu(z)
        x = x + mm(y, pre + "Wout")
        new_conv.append(cs)
        new_ssm.append(hl)
    x = cm.rmsnorm(x, params["norm_f.w"])
    logits = x @ eff("head")
    return logits, jnp.stack(new_conv), jnp.stack(new_ssm)


def decode_step(params, eff, spec, token, conv_states, ssm_states):
    """Single-token stepwise decode using recurrent state.

    token (B,) int32; conv_states (n_layer, B, K-1, Di);
    ssm_states (n_layer, B, Di, H). Returns (logits (B, V), states').
    """
    x = params["embed"][token]                        # (B, Dm)
    new_conv, new_ssm = [], []
    for i in range(spec.n_layer):
        pre = f"layers.{i}."
        un = cm.rmsnorm(x, params[pre + "norm.w"])
        xi = un @ eff(pre + "Win_x")
        z = un @ eff(pre + "Win_z")
        xi, cs = cm.conv1d_step(xi, conv_states[i], params[pre + "conv.w"],
                                params[pre + "conv.b"])
        xi = cm.silu(xi)
        delta, A, Bm, C = _ssm_params(params, eff, pre, spec, xi[:, None, :])
        y, hl = selective_scan(xi[:, None, :], delta, A, Bm, C, ssm_states[i])
        y = y[:, 0, :] + params[pre + "Dskip"][None, :] * xi
        y = y * cm.silu(z)
        x = x + y @ eff(pre + "Wout")
        new_conv.append(cs)
        new_ssm.append(hl)
    x = cm.rmsnorm(x, params["norm_f.w"])
    logits = x @ eff("head")
    return logits, jnp.stack(new_conv), jnp.stack(new_ssm)
