"""Deep S4 layer and models (paper Eq. 4 and Sec. 6.1).

A deep S4 layer is  y_t = ReLU(W · S4_t(x) + β + u ⊙ x_t)  with per-channel
LTI SSM parameters (A diagonal, B, C, log-Δ), a position-wise linear layer
(W, β) and a residual coefficient u.

Two model flavours:
  s4lm  — embedding → L deep-S4 layers → RMSNorm → LM head (token tasks,
          Table 19 pixel classification analogue).
  s4reg — raw vector-sequence regression, no embedding/head: the synthetic
          Fig. 2 / Fig. 6 setting (1-layer target vs deeper frozen model).

Parameter names (layer i prefix "layers.{i}."):
  A_log (D, H)   A = -exp(A_log)
  B     (D, H)   input transition (continuous)
  C     (D, H)   output map
  log_dt (D,)    per-channel step size
  W     (D, D)   position-wise linear
  beta  (D,)     bias
  u     (D,)     residual coefficient
s4lm adds embed (V, D), norm_f.w (D,), head (D, V).

Discretization: ZOH  Ābar = exp(Δ A), B̄bar = Δ B (paper's simplification).
PEFT hooks: eff() for W (LoRA/DoRA), "layers.{i}.h0" initial states, model
"prompt" (s4lm), per-layer "prefix" (s4lm).
"""

import jax
import jax.numpy as jnp

from ..kernels import s4_scan
from . import common as cm


def init_params(rng, spec, activation="relu"):
    p = {}
    ks = iter(jax.random.split(rng, 4 + 8 * spec.n_layer))
    D, H = spec.d_model, spec.d_state
    if not spec.is_reg:
        p["embed"] = 0.02 * jax.random.normal(next(ks), (spec.vocab, D))
        p["norm_f.w"] = jnp.ones((D,))
        p["head"] = cm.glorot(next(ks), (D, spec.vocab))
    for i in range(spec.n_layer):
        pre = f"layers.{i}."
        p[pre + "A_log"] = cm.init_a_log(next(ks), D, H)
        p[pre + "B"] = jax.random.normal(next(ks), (D, H)) / (H ** 0.5)
        p[pre + "C"] = jax.random.normal(next(ks), (D, H)) / (H ** 0.5)
        p[pre + "log_dt"] = cm.init_log_dt(next(ks), D, 1e-2, 0.5)
        p[pre + "W"] = cm.glorot(next(ks), (D, D))
        p[pre + "beta"] = jnp.zeros((D,))
        p[pre + "u"] = jnp.ones((D,))
    return p


def discretize(params, eff, pre):
    """ZOH-discretized per-channel (Ābar, B̄bar).

    A_log/B go through eff() so LoRA-on-SSM (Fig. 2's baseline, which
    treats the stacked diagonal A as a (D, H) matrix) composes here.
    """
    A = -jnp.exp(eff(pre + "A_log"))                 # (D, H)
    dt = jnp.exp(params[pre + "log_dt"])[:, None]    # (D, 1)
    Abar = jnp.exp(dt * A)
    Bbar = dt * eff(pre + "B")
    return Abar, Bbar


def layer(params, eff, pre, spec, x, activation="relu"):
    """One deep S4 layer. x (B, L, D) -> (B, L, D)."""
    Bsz, L, D = x.shape
    M = 0
    xin = x
    if pre + "prefix" in params:
        P = params[pre + "prefix"]
        M = P.shape[0]
        xin = jnp.concatenate([jnp.tile(P[None], (Bsz, 1, 1)), xin], axis=1)
    Abar, Bbar = discretize(params, eff, pre)
    if pre + "h0" in params:
        h0 = jnp.tile(params[pre + "h0"][None], (Bsz, 1, 1))
    else:
        h0 = jnp.zeros((Bsz, D, spec.d_state), x.dtype)
    s4out, _ = s4_scan(xin, Abar, Bbar, eff(pre + "C"), h0)
    y = s4out @ eff(pre + "W") + params[pre + "beta"] \
        + params[pre + "u"][None, None, :] * xin
    if activation == "relu":
        y = jax.nn.relu(y)
    if M:
        y = y[:, M:, :]
    return y


def forward_reg(params, eff, spec, x, activation="relu"):
    """Regression model: x (B, L, D) float -> y (B, L, D)."""
    for i in range(spec.n_layer):
        act = activation if i + 1 < spec.n_layer else "none"
        x = layer(params, eff, f"layers.{i}.", spec, x, act)
    return x


def forward(params, eff, spec, tokens):
    """LM model: tokens (B, L) -> logits (B, L, V)."""
    x = params["embed"][tokens]
    if "prompt" in params:
        P = params["prompt"]
        x = jnp.concatenate([jnp.tile(P[None], (x.shape[0], 1, 1)), x], axis=1)
    for i in range(spec.n_layer):
        x = layer(params, eff, f"layers.{i}.", spec, x)
    x = cm.rmsnorm(x, params["norm_f.w"])
    logits = x @ eff("head")
    if "prompt" in params:
        logits = logits[:, params["prompt"].shape[0]:, :]
    return logits
