"""Layer-2 SSM model zoo."""
